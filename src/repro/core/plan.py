"""Physical-plan IR — the bridge between synthesized LLQL and execution.

DBFlex generates specialized C++ straight from the annotated LLQL program;
here the same role is split in two: ``core.lower.compile`` turns the LLQL
program into this small physical-plan IR, and an *executor* realizes the
plan — single-shard (``repro.exec.engine.execute_plan``) or sharded under
``shard_map`` (``repro.exec.distributed.execute_plan_sharded``).  The plan is
the paper's "generated engine" made explicit as data: every dictionary-
producing node carries the ``DictChoice`` the synthesizer made for it, so one
plan object serves costing, single-core execution, and scale-out.

Node vocabulary (DESIGN.md §3):

* ``Scan``      — bind a loop variable over a base relation, a derived
                  relation (a previous join/projection output), or the
                  key/value pairs of a materialized dictionary (dict-scan);
* ``Select``    — static-shape filter (mask, never compaction);
* ``Project``   — materialize named columns from the current frame; the
                  output is a *relation* downstream Scans can iterate;
* ``HashBuild`` — key → row-index dictionary (join index) with its choice;
* ``HashProbe`` — probe a built index, binding the inner loop variable to
                  the gathered build-side row (FK join);
* ``GroupBy``   — dictionary aggregate build (Fig. 6c/6d);
* ``GroupJoin`` — Fig. 6e/6f compound probe+aggregate;
* ``Reduce``    — scalar aggregation into a ref, with the optional
                  interleaved lookup of Fig. 7b;
* ``Exchange``  — cross-shard merge of a per-shard dictionary (shuffle by
                  key hash, or all-reduce for scalar refs).  Identity on a
                  single shard.
* ``Repartition`` — cross-shard movement of *rows* (a frame): ``hash``
                  routes every row to the shard owning ``hash(keyexpr)``,
                  ``broadcast`` all-gathers the rows onto every shard.
                  Identity on a single shard.

Distribution is planned, not hard-coded: every symbol carries a
*partitioning property* — :class:`Replicated`, :class:`ShardedArbitrary`, or
:class:`HashPartitioned` — and :func:`legalize` converts between properties
by inserting explicit ``Repartition``/``Exchange`` nodes (DESIGN.md §4).

Expressions inside nodes are LLQL row expressions over the loop variables
bound by the node chain (``Scan.var`` / ``HashProbe.inner_var``); executors
compile them to columnar jnp values.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple, Union

from . import llql as L
from .cost import DictChoice, GammaDict


@dataclass(frozen=True)
class Node:
    out: str  # symbol this node defines (frame, relation, dict, or ref)


@dataclass(frozen=True)
class Scan(Node):
    source: str  # base relation, derived relation symbol, or dict symbol
    var: str  # LLQL loop variable bound to the rows


@dataclass(frozen=True)
class Select(Node):
    source: str
    pred: L.Expr  # row predicate over the frame's bound variables


@dataclass(frozen=True)
class Project(Node):
    source: str
    fields: Tuple[Tuple[str, L.Expr], ...]  # name -> row expression


@dataclass(frozen=True)
class HashBuild(Node):
    source: str
    keyexpr: L.Expr
    choice: DictChoice
    hinted: bool = False  # program-level hinted insert (Fig. 6b/6d form)


@dataclass(frozen=True)
class GroupBy(Node):
    source: str
    keyexpr: L.Expr
    values: Tuple[Tuple[str, L.Expr], ...]  # aggregate lanes
    choice: DictChoice
    hinted: bool = False


@dataclass(frozen=True)
class HashProbe(Node):
    source: str
    build: str  # HashBuild output symbol
    keyexpr: L.Expr
    inner_var: str  # variable bound to the matched build-side row
    hinted: bool = False  # program-level hinted lookup (merge form)


@dataclass(frozen=True)
class GroupJoin(Node):
    source: str
    build: str  # GroupBy output symbol holding g-side partial aggregates
    keyexpr: L.Expr
    f_expr: L.Expr  # multiplicand over the probe side (lookup stripped)
    choice: DictChoice
    hinted: bool = False


@dataclass(frozen=True)
class Reduce(Node):
    source: str
    fields: Tuple[Tuple[str, L.Expr], ...]
    lookup_sym: Optional[str] = None  # Fig. 7b interleaved lookup
    lookup_key: Optional[L.Expr] = None
    lookup_var: Optional[str] = None


@dataclass(frozen=True)
class Exchange(Node):
    source: str  # per-shard dictionary symbol to merge
    kind: str  # "shuffle" | "allreduce"
    choice: DictChoice = field(default_factory=DictChoice)


@dataclass(frozen=True)
class Repartition(Node):
    """Move frame rows across shards: ``hash`` routes each row to the shard
    owning ``hash(keyexpr)`` (the dictionaries' own mix, so a dictionary
    built after a hash repartition is co-partitioned with every other symbol
    hashed on the same key values); ``broadcast`` all-gathers the rows so
    every shard holds all of them.  Identity on a single shard."""

    source: str  # frame symbol to move
    kind: str  # "hash" | "broadcast"
    keyexpr: Optional[L.Expr] = None  # hash only: partitioning expression


DICT_NODES = (HashBuild, GroupBy, GroupJoin)


# ---------------------------------------------------------------------------
# Partitioning properties
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Replicated:
    """Every shard holds the full data (dimension tables, merged scalars)."""


@dataclass(frozen=True)
class ShardedArbitrary:
    """Rows are split across shards with no key alignment; ``rel`` names the
    sharded base relation the rows descend from ("?" when mixed/derived)."""

    rel: str = "?"


@dataclass(frozen=True)
class HashPartitioned:
    """Rows/entries are owned by ``hash(key) % n_shards``.

    ``key`` is the partitioning witness: an LLQL expression for frames (the
    routed key expression, compared structurally for co-partitioning), a
    column name for relations (Project outputs), and ``None`` for
    dictionaries — a dictionary is always partitioned by its own key."""

    key: Optional[object] = None


Partitioning = Union[Replicated, ShardedArbitrary, HashPartitioned]


@dataclass(frozen=True)
class Plan:
    nodes: Tuple[Node, ...]
    result: Optional[str]  # symbol of the program result (None: ref record)
    choices: Tuple[Tuple[str, DictChoice], ...] = ()
    # free query parameters: (name, scalar kind) — row expressions inside
    # nodes may reference them as ``L.Param``; executors receive the values
    # at call time (as traced jit arguments, so rebinding never re-traces)
    params: Tuple[Tuple[str, str], ...] = ()

    def choice_map(self) -> GammaDict:
        return dict(self.choices)

    def param_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.params)

    def bind(self, bindings: Optional[Dict[str, object]] = None, **kw) -> "BoundPlan":
        """Attach parameter values — a cheap substitution, not a recompile.
        The returned ``BoundPlan`` is accepted everywhere a ``Plan`` is; the
        values are passed to the (cached) executable as runtime arrays."""
        vals = {**(bindings or {}), **kw}
        unknown = set(vals) - set(self.param_names())
        if unknown:
            raise KeyError(f"unknown parameters {sorted(unknown)}")
        missing = set(self.param_names()) - set(vals)
        if missing:
            raise KeyError(f"missing bindings for {sorted(missing)}")
        return BoundPlan(self, tuple(sorted(vals.items())))

    def fingerprint(self) -> str:
        """Stable structural identity of the plan — node tree (including row
        expressions and baked constants), result symbol, per-dictionary
        choices, and free parameters.  Two plans with equal fingerprints
        compute the same function of (database, parameter values); the
        executable cache keys on it."""
        import hashlib

        blob = repr((self.nodes, self.result, self.choices, self.params))
        return hashlib.sha1(blob.encode()).hexdigest()

    def node_defining(self, sym: str) -> Optional[Node]:
        for n in self.nodes:
            if n.out == sym:
                return n
        return None

    def dict_nodes(self) -> Iterator[Node]:
        for n in self.nodes:
            if isinstance(n, DICT_NODES):
                yield n

    def describe(self) -> str:
        """Stable one-line-per-node rendering (golden tests, explain)."""
        lines = []
        for n in self.nodes:
            if isinstance(n, Scan):
                lines.append(f"Scan {n.out} <- {n.source} as {n.var}")
            elif isinstance(n, Select):
                lines.append(f"Select {n.out} <- {n.source}")
            elif isinstance(n, Project):
                cols = ",".join(a for a, _ in n.fields)
                lines.append(f"Project {n.out} <- {n.source} [{cols}]")
            elif isinstance(n, HashBuild):
                lines.append(f"HashBuild {n.out} <- {n.source} [{n.choice}]")
            elif isinstance(n, GroupBy):
                lanes = ",".join(a for a, _ in n.values)
                lines.append(
                    f"GroupBy {n.out} <- {n.source} [{n.choice}] lanes={lanes}"
                )
            elif isinstance(n, HashProbe):
                lines.append(
                    f"HashProbe {n.out} <- {n.source} ⋈ {n.build} as {n.inner_var}"
                )
            elif isinstance(n, GroupJoin):
                lines.append(f"GroupJoin {n.out} <- {n.source} ⋈ {n.build} [{n.choice}]")
            elif isinstance(n, Reduce):
                lanes = ",".join(a for a, _ in n.fields)
                lk = f" lookup={n.lookup_sym}" if n.lookup_sym else ""
                lines.append(f"Reduce {n.out} <- {n.source} lanes={lanes}{lk}")
            elif isinstance(n, Exchange):
                lines.append(
                    f"Exchange {n.out} <- {n.source} ({n.kind}) [{n.choice}]"
                )
            elif isinstance(n, Repartition):
                how = (
                    f"hash {L.pretty(n.keyexpr)}"
                    if n.kind == "hash"
                    else n.kind
                )
                lines.append(f"Repartition {n.out} <- {n.source} ({how})")
            else:  # pragma: no cover
                lines.append(repr(n))
        lines.append(f"Result {self.result}")
        return "\n".join(lines)


@dataclass(frozen=True)
class BoundPlan:
    """A plan plus parameter values: the unit of a serving request.  Binding
    is O(#params) — no synthesis, no lowering, no tracing happens here."""

    plan: Plan
    bindings: Tuple[Tuple[str, object], ...]

    def binding_map(self) -> Dict[str, object]:
        return dict(self.bindings)


class PlanShardError(Exception):
    """The plan cannot be realized under the sharded executor.  Since the
    partitioning-property legalizer replaced the taint-bit analysis this is
    reserved for genuinely unknown node kinds — sharded builds, probes of
    sharded dictionaries, and sharded groupjoins/reduce-lookups all legalize
    into Repartition/Exchange nodes instead of raising."""


def _frame_key(var: str, col: Optional[str] = None) -> L.Expr:
    """Partitioning witness for a frame bound by ``Scan(var)``: the key of a
    dict scan (``var.key``) or a named column (``var.key.col``)."""
    key = L.FieldAccess(L.Var(var), "key")
    return key if col is None else L.FieldAccess(key, col)


def legalize(
    plan: Plan, sharded_rels: Tuple[str, ...]
) -> Tuple[Plan, Dict[str, Partitioning]]:
    """Rewrite a single-shard plan for sharded execution by tracking a
    partitioning property per symbol and inserting explicit conversion nodes
    (DESIGN.md §4).  Returns ``(plan', props)``.

    * A dictionary built from sharded rows is *placed*: ``partition`` (the
      default) hash-repartitions the build rows by the build key and builds
      per-shard slices; ``broadcast`` (``DictChoice.placement``) all-gathers
      the rows and builds a replicated copy.  The choice is made by synthesis
      under Δ_net, not hard-coded here.
    * A probe of a hash-partitioned dictionary repartitions the probe side to
      match (co-partitioned join) — unless the probe frame is already
      partitioned on the same key expression (elided), or replicated (each
      shard's found-mask then selects exactly the keys it owns: a
      "mask-partitioned" probe needing no data movement).
    * ``GroupBy``/``GroupJoin`` over sharded rows keep the per-shard partial
      + shuffle-``Exchange`` form, but the Exchange is *elided* when the
      input frame is already hash-partitioned on the group key.
    * Scalar ``Reduce`` results over sharded (or mask-partitioned) rows get
      an all-reduce ``Exchange``.
    """
    props: Dict[str, Partitioning] = {}
    out_nodes: List[Node] = []
    fresh_ctr = [0]

    def prop(sym: str) -> Partitioning:
        return props.get(sym, Replicated())

    def emit(n: Node) -> None:
        out_nodes.append(n)

    def repartitioned(frame: str, keyexpr: L.Expr) -> str:
        """Frame symbol holding ``frame``'s rows hash-routed by ``keyexpr``."""
        p = prop(frame)
        if isinstance(p, HashPartitioned) and p.key == keyexpr:
            return frame
        out = f"{frame}#part{fresh_ctr[0]}"
        fresh_ctr[0] += 1
        emit(Repartition(out, source=frame, kind="hash", keyexpr=keyexpr))
        props[out] = HashPartitioned(keyexpr)
        return out

    def broadcasted(frame: str) -> str:
        """Frame symbol holding ``frame``'s rows gathered onto every shard."""
        if isinstance(prop(frame), Replicated):
            return frame
        out = f"{frame}#bcast{fresh_ctr[0]}"
        fresh_ctr[0] += 1
        emit(Repartition(out, source=frame, kind="broadcast"))
        props[out] = Replicated()
        return out

    def copartitioned(frame: str, keyexpr: L.Expr) -> bool:
        p = prop(frame)
        return isinstance(p, HashPartitioned) and p.key == keyexpr

    def partial_with_exchange(n: Node) -> None:
        local = _rename(n, n.out + "#local")
        emit(local)
        props[local.out] = ShardedArbitrary()
        emit(Exchange(n.out, source=local.out, kind="shuffle", choice=n.choice))
        props[n.out] = HashPartitioned()  # merged slices own their key hashes

    for n in plan.nodes:
        if isinstance(n, Scan):
            if n.source in sharded_rels:
                props[n.out] = ShardedArbitrary(n.source)
            else:
                p = prop(n.source)
                if isinstance(p, HashPartitioned):
                    # dict scan / derived relation: partitioned-by-own-key
                    # becomes partitioned on the bound variable's key expr
                    col = p.key if isinstance(p.key, str) else None
                    props[n.out] = HashPartitioned(_frame_key(n.var, col))
                else:
                    props[n.out] = p
            emit(n)
        elif isinstance(n, Select):
            props[n.out] = prop(n.source)  # masking moves no rows
            emit(n)
        elif isinstance(n, Project):
            p = prop(n.source)
            if isinstance(p, HashPartitioned):
                # partitioned on a projected column iff some output column is
                # exactly the partitioning expression
                cols = [a for a, fx in n.fields if fx == p.key]
                props[n.out] = (
                    HashPartitioned(cols[0]) if cols else ShardedArbitrary()
                )
            else:
                props[n.out] = p
            emit(n)
        elif isinstance(n, HashBuild):
            p = prop(n.source)
            if isinstance(p, Replicated):
                props[n.out] = Replicated()
                emit(n)
            elif copartitioned(n.source, n.keyexpr):
                props[n.out] = HashPartitioned()
                emit(n)
            elif getattr(n.choice, "placement", "") == "broadcast":
                emit(_resrc(n, broadcasted(n.source)))
                props[n.out] = Replicated()
            else:  # co-partitioned placement (default)
                emit(_resrc(n, repartitioned(n.source, n.keyexpr)))
                props[n.out] = HashPartitioned()
        elif isinstance(n, HashProbe):
            bp = prop(n.build)
            if isinstance(bp, Replicated):
                props[n.out] = prop(n.source)
                emit(n)
            elif isinstance(prop(n.source), Replicated):
                # replicated probe rows against a partitioned dict: the local
                # found-mask keeps exactly the keys this shard owns — the
                # result is hash-partitioned with zero data movement
                props[n.out] = HashPartitioned(n.keyexpr)
                emit(n)
            else:
                src = (
                    n.source
                    if copartitioned(n.source, n.keyexpr)
                    else repartitioned(n.source, n.keyexpr)
                )
                props[n.out] = HashPartitioned(n.keyexpr)
                emit(_resrc(n, src))
        elif isinstance(n, GroupBy):
            p = prop(n.source)
            if isinstance(p, Replicated):
                props[n.out] = Replicated()
                emit(n)
            elif copartitioned(n.source, n.keyexpr):
                # input already owns its group keys: elide the Exchange
                props[n.out] = HashPartitioned()
                emit(n)
            else:
                partial_with_exchange(n)
        elif isinstance(n, GroupJoin):
            # probes ``build`` and aggregates by the *same* key expression
            bp = prop(n.build)
            p = prop(n.source)
            if isinstance(bp, Replicated):
                if isinstance(p, Replicated):
                    props[n.out] = Replicated()
                    emit(n)
                elif copartitioned(n.source, n.keyexpr):
                    props[n.out] = HashPartitioned()
                    emit(n)
                else:
                    partial_with_exchange(n)
            else:
                # partitioned build: align the probe side (or ride the
                # mask-partition of a replicated frame) — the aggregate is
                # then disjoint by key and needs no Exchange
                if isinstance(p, Replicated) or copartitioned(
                    n.source, n.keyexpr
                ):
                    src = n.source
                else:
                    src = repartitioned(n.source, n.keyexpr)
                props[n.out] = HashPartitioned()
                emit(_resrc(n, src))
        elif isinstance(n, Reduce):
            src = n.source
            lp = (
                prop(n.lookup_sym) if n.lookup_sym is not None else Replicated()
            )
            if isinstance(lp, HashPartitioned) and not isinstance(
                prop(src), Replicated
            ):
                # align sharded rows with the partitioned dictionary — a
                # no-op when already co-partitioned on the lookup key;
                # replicated rows ride the found-mask instead
                src = repartitioned(src, n.lookup_key)
            emit(_resrc(n, src))
            sharded_rows = not isinstance(prop(src), Replicated)
            mask_partitioned = isinstance(lp, HashPartitioned)
            if sharded_rows or mask_partitioned:
                emit(Exchange(n.out + "#sum", source=n.out, kind="allreduce"))
            props[n.out] = Replicated()  # all-reduced scalar record
        elif isinstance(n, (Exchange, Repartition)):
            raise PlanShardError(f"plan already legalized at {n.out}")
        else:  # pragma: no cover
            raise PlanShardError(f"unknown node {type(n).__name__}")

    return Plan(tuple(out_nodes), plan.result, plan.choices, plan.params), props


def _rename(n: Node, new_out: str) -> Node:
    import dataclasses

    return dataclasses.replace(n, out=new_out)


def _resrc(n: Node, new_source: str) -> Node:
    import dataclasses

    if n.source == new_source:  # type: ignore[attr-defined]
        return n
    return dataclasses.replace(n, source=new_source)
