"""Serving-time adaptive planning: race → validate → recalibrate.

Algorithm 1 commits to dictionary/fusion/placement choices from an offline
cost model before a single row is touched.  PR-5 calibration gets the model
to 0.98 rank agreement — which still misranks real pairs, and a misrank on
the critical dictionary of a hot query is paid on every request.  This
module closes the loop at serving time (DESIGN.md §11):

* :func:`enumerate_candidates` — the Alg.-1 winner plus its single-symbol
  neighborhood (every alternative ``DictChoice`` for every dictionary,
  re-costed by the full-program ``infer_cost``), filtered to the top-k
  candidates whose modeled cost is within ``(1 + band)`` of the winner's.
  When the model is sure, the band is empty and nothing is raced; when
  candidates are within noise of each other, measurement decides.
* :class:`AdaptivePlanner` — races the candidates on warm-up (or sampled
  live) traffic, validates every raced result **bitwise** against the
  model-chosen plan (the same equivalence contract as the fused ==
  materialized machinery), caches the measured winner per ``(plan
  fingerprint, binding bucket)``, and feeds measured-vs-predicted
  residuals back into ``AnalyticCostModel.apply_residual`` so the model's
  per-op correction table improves as the server runs.

The planner is executor-agnostic: callers hand it ``make_executor(choices)
-> callable(params) -> result`` (single-shard executable, streamed
executable, or sharded executor — ``repro.session.Session`` wires all
three), so racing works unchanged out-of-core and across shards.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import llql as L
from .cardinality import CardModel
from .cost import CostResult, DictChoice, GammaDict, infer_cost
from .synthesis import DEFAULT_CANDIDATES, _candidates_for, synthesize


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdaptConfig:
    """Knobs of the adaptive loop.

    ``band``/``top_k`` bound the race (candidates within ``(1+band)×`` of
    the modeled winner, at most ``top_k`` raced); ``warmup`` is how many
    requests per binding bucket race before the winner freezes;
    ``sample_every`` re-races every Nth steady-state request (0 = never:
    after warm-up the cached winner serves with zero planning overhead);
    ``repeats`` timing repeats per candidate (min taken — races measure
    best-case dispatch, not scheduler noise); ``residual_alpha`` the
    geometric step of :meth:`AnalyticCostModel.apply_residual`;
    ``validate`` turns the bitwise result check off (benchmarks only)."""

    band: float = 0.25
    top_k: int = 3
    warmup: int = 1
    sample_every: int = 0
    repeats: int = 2
    residual_alpha: float = 0.5
    validate: bool = True


# ---------------------------------------------------------------------------
# binding buckets
# ---------------------------------------------------------------------------


def binding_bucket(params: Optional[Dict[str, object]]) -> Tuple:
    """Coarse equivalence class of a parameter binding.

    The measured winner of a race is a property of the *data volumes* the
    binding selects, not the exact binding: Q18 at threshold 199 and 201
    want the same plan, Q18 at 0.0 (every group survives) may not.  Floats
    bucket by the rounded log2 of their magnitude (decade-ish resolution),
    ints and strings by value (TPC-H's int knobs — region, color — change
    selectivity per value), so the winner cache neither explodes per
    binding nor conflates regimes."""
    if not params:
        return ()
    out = []
    for name in sorted(params):
        v = params[name]
        if isinstance(v, bool) or isinstance(v, (int, np.integer)):
            out.append((name, int(v)))
        elif isinstance(v, (float, np.floating)):
            a = abs(float(v))
            out.append((name, round(np.log2(a)) if a > 1e-12 else None))
        else:
            out.append((name, str(v)))
    return tuple(out)


def choices_key(choices: GammaDict) -> Tuple:
    """Canonical hashable identity of a Γ assignment."""
    return tuple(
        (sym, c.ds, bool(c.hinted), c.placement or "")
        for sym, c in sorted(choices.items())
    )


# ---------------------------------------------------------------------------
# candidate enumeration (the race roster)
# ---------------------------------------------------------------------------


@dataclass
class Candidate:
    choices: GammaDict
    modeled_s: float
    cost: CostResult
    swapped: str = ""  # symbol whose choice differs from the winner ("" = winner)

    @property
    def key(self) -> Tuple:
        return choices_key(self.choices)


def enumerate_candidates(
    expr: L.Expr,
    sigma: CardModel,
    delta,
    band: float = 0.25,
    top_k: int = 3,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    net=None,
    sharded_rels: Optional[Tuple[str, ...]] = None,
) -> List[Candidate]:
    """Alg.-1 winner + its near-cost single-symbol neighborhood.

    Runs the greedy synthesis, then prices every single-symbol swap of the
    winning Γ with the full-program ``infer_cost`` (the same objective the
    greedy minimized), keeps swaps within ``(1 + band)×`` of the winner's
    modeled cost, and returns the ``top_k`` cheapest — winner always first
    (it is the validation reference even when a swap models cheaper, which
    the greedy's known sub-optimality permits)."""
    syn = synthesize(
        expr, sigma, delta, candidates=candidates,
        net=net, sharded_rels=sharded_rels,
    )
    winner = Candidate(dict(syn.choices), syn.cost.total, syn.cost)
    limit = winner.modeled_s * (1.0 + max(0.0, band))
    seen = {winner.key}
    pool: List[Candidate] = []
    for sym in sorted(syn.choices):
        for alt in _candidates_for(sym, expr, candidates):
            trial = dict(syn.choices)
            trial[sym] = alt
            k = choices_key(trial)
            if k in seen:
                continue
            seen.add(k)
            res = infer_cost(
                expr, sigma, delta, trial, net=net, sharded_rels=sharded_rels
            )
            if res.total <= limit:
                pool.append(Candidate(trial, res.total, res, swapped=sym))
    pool.sort(key=lambda c: c.modeled_s)
    return [winner] + pool[: max(0, top_k - 1)]


# ---------------------------------------------------------------------------
# bitwise result validation
# ---------------------------------------------------------------------------


def result_items(out) -> Dict[int, np.ndarray]:
    """Normalize any executor result to its ``{key: np.ndarray}`` view."""
    if hasattr(out, "items_np"):
        return out.items_np()
    if isinstance(out, dict):
        return {k: np.asarray(v) for k, v in out.items()}
    raise TypeError(f"cannot normalize result of type {type(out).__name__}")


def bitwise_equal(a: Dict[int, np.ndarray], b: Dict[int, np.ndarray]) -> bool:
    """Exact equality: same key set, identical value bytes per key — the
    equivalence contract the fused==materialized tests enforce.  All four
    dictionary families produce bitwise-identical results for the TPC-H
    suite (same row order, same f32 folds), so a raced candidate that
    deviates by even one ulp is a planner bug, not noise."""
    if set(a) != set(b):
        return False
    for k, va in a.items():
        vb = b[k]
        va, vb = np.asarray(va), np.asarray(vb)
        if va.shape != vb.shape or va.dtype != vb.dtype:
            return False
        if not (va == vb).all():
            return False
    return True


def _block(out) -> None:
    """Force completion of an executor result for timing purposes."""
    import jax

    if hasattr(out, "arrays"):
        jax.block_until_ready(out.arrays())
    elif hasattr(out, "items_np"):
        jax.block_until_ready(getattr(out, "vals", None) or out.items_np())
    else:
        jax.block_until_ready(out)


# ---------------------------------------------------------------------------
# the adaptive planner
# ---------------------------------------------------------------------------


@dataclass
class Lane:
    """One raced candidate's outcome."""

    candidate: Candidate
    measured_s: float = float("inf")
    validated: bool = False


@dataclass
class RaceRecord:
    bucket: Tuple
    lanes: List[Lane] = field(default_factory=list)
    winner_key: Tuple = ()

    @property
    def winner(self) -> Optional[Lane]:
        for lane in self.lanes:
            if lane.candidate.key == self.winner_key:
                return lane
        return None


class AdaptivePlanner:
    """Race / validate / recalibrate for ONE query shape (LLQL program).

    ``make_executor(choices)`` must return a callable ``run(params) ->
    result`` that blocks until the result is ready (the engine executables
    do; sharded results are blocked via their arrays).  Executors are
    cached per Γ so racing never re-jits on later rounds; the winner per
    ``(fingerprint, binding bucket)`` serves steady-state traffic with no
    replanning — ``choose`` is a dict lookup."""

    def __init__(
        self,
        expr: L.Expr,
        sigma: CardModel,
        delta,
        make_executor: Callable[[GammaDict], Callable],
        config: Optional[AdaptConfig] = None,
        fingerprint: str = "",
        candidates: Sequence[str] = DEFAULT_CANDIDATES,
        net=None,
        sharded_rels: Optional[Tuple[str, ...]] = None,
    ):
        self.expr = expr
        self.sigma = sigma
        self.delta = delta
        self.make_executor = make_executor
        self.config = config or AdaptConfig()
        self.fingerprint = fingerprint
        self.candidates = tuple(candidates)
        self.net = net
        self.sharded_rels = sharded_rels
        self.winners: Dict[Tuple, GammaDict] = {}
        self.races: List[RaceRecord] = []
        self._counts: Dict[Tuple, int] = {}
        self._executors: Dict[Tuple, Callable] = {}

    # -- steady-state entry point -------------------------------------------
    def choose(self, params: Optional[Dict[str, object]] = None) -> GammaDict:
        """The Γ to execute this request under.  Races on the first
        ``warmup`` requests of each binding bucket (and every
        ``sample_every``-th after, when sampling is on); otherwise returns
        the cached winner without touching the cost model."""
        bucket = binding_bucket(params)
        key = (self.fingerprint, bucket)
        n = self._counts.get(bucket, 0)
        self._counts[bucket] = n + 1
        cfg = self.config
        race_now = (
            key not in self.winners
            or n < cfg.warmup
            or (cfg.sample_every and (n % cfg.sample_every) == 0)
        )
        if race_now:
            self.race(params)
        return self.winners[key]

    def executor_for(self, choices: GammaDict) -> Callable:
        k = choices_key(choices)
        ex = self._executors.get(k)
        if ex is None:
            ex = self._executors[k] = self.make_executor(dict(choices))
        return ex

    # -- one race round ------------------------------------------------------
    def race(self, params: Optional[Dict[str, object]] = None) -> RaceRecord:
        """Enumerate the near-cost candidates under the CURRENT (corrected)
        cost model, run each on this binding, validate bitwise against the
        model-chosen reference, time the validated ones, install the
        measured winner, and push residuals into the correction table."""
        cfg = self.config
        bucket = binding_bucket(params)
        cands = enumerate_candidates(
            self.expr, self.sigma, self.delta,
            band=cfg.band, top_k=cfg.top_k, candidates=self.candidates,
            net=self.net, sharded_rels=self.sharded_rels,
        )
        record = RaceRecord(bucket)
        reference: Optional[Dict[int, np.ndarray]] = None
        for cand in cands:
            lane = Lane(cand)
            record.lanes.append(lane)
            ex = self.executor_for(cand.choices)
            out = ex(params)  # warm/trace call, untimed
            items = result_items(out)
            if reference is None:
                reference = items  # model winner IS the reference
                lane.validated = True
            else:
                lane.validated = (not cfg.validate) or bitwise_equal(
                    items, reference
                )
            if not lane.validated:
                continue  # never adopt (or learn from) an unvalidated lane
            best = float("inf")
            for _ in range(max(1, cfg.repeats)):
                t0 = time.perf_counter()
                _block(ex(params))
                best = min(best, time.perf_counter() - t0)
            lane.measured_s = best
            self._recalibrate(cand, best)
        winner = min(
            (ln for ln in record.lanes if ln.validated),
            key=lambda ln: ln.measured_s,
        )
        record.winner_key = winner.candidate.key
        self.winners[(self.fingerprint, bucket)] = dict(winner.candidate.choices)
        self.races.append(record)
        return record

    # -- residual feedback ---------------------------------------------------
    def _recalibrate(self, cand: Candidate, measured_s: float) -> None:
        """One ``apply_residual`` step per dominant op of the candidate.

        The measured/predicted ratio of a whole plan is attributed to the
        (ds, op[, ordered]) keys that dominate its modeled dictionary cost
        (≥ 20% share) — blaming every op equally would smear a single
        mispriced coefficient across the table; blaming only the top one
        starves multi-dictionary plans.  Predictions use the corrections
        already applied, so repeated consistent races converge the factors
        instead of double-counting."""
        apply_residual = getattr(self.delta, "apply_residual", None)
        op_key = getattr(self.delta, "op_key", None)
        if apply_residual is None or op_key is None:
            return  # learned / foreign Δ: racing still works, learning is off
        if not (measured_s > 0.0) or not (cand.modeled_s > 0.0):
            return
        ratio = measured_s / cand.modeled_s
        by_key: Dict[Tuple, List] = {}
        dict_total = 0.0
        for it in cand.cost.items:
            try:
                k = op_key(it.ds, it.op, it.ordered)
            except KeyError:
                continue
            by_key.setdefault(k, []).append(it)
            dict_total += it.seconds
        if dict_total <= 0.0:
            return
        for k, items in by_key.items():
            share = sum(it.seconds for it in items) / dict_total
            if share < 0.2:
                continue
            rep = max(items, key=lambda it: it.seconds)
            apply_residual(
                rep.ds, rep.op, rep.ordered, ratio, alpha=self.config.residual_alpha
            )
