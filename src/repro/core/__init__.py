from . import llql  # noqa: F401
from .cardinality import CardModel, ColumnStats, RelStats  # noqa: F401
from .cost import AnalyticCostModel, DictChoice, infer_cost  # noqa: F401
from .synthesis import synthesize, synthesize_exhaustive  # noqa: F401
