"""Program synthesis — the paper's Algorithm 1 (greedy data-structure choice).

Given an LLQL program with open ``@ds`` annotations, a cardinality model Σ and
a dictionary cost model Δ, pick per dictionary symbol the implementation (and,
for sort-based families, whether its access sites use the hinted/merge form)
that minimises the inferred program cost.

Exactly as in the paper:
* symbols are visited in dependency order (a dictionary that is *probed while
  building another* is decided first);
* each decision evaluates the full-program cost with the candidate choice and
  the already-fixed choices (Γ), remaining symbols at their defaults;
* ties and local optima: the paper notes the greedy can be sub-optimal when
  dictionaries are iterated downstream (e.g. Q18, in-DB ML); we additionally
  provide ``synthesize_exhaustive`` for small programs, used in tests to
  check the greedy's optimality gap.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from . import llql as L
from .cardinality import CardModel
from .cost import CostResult, DictChoice, DictCostModel, GammaDict, infer_cost

DEFAULT_CANDIDATES: Tuple[str, ...] = (
    "ht_linear",
    "ht_twochoice",
    "st_sorted",
    "st_blocked",
)


@dataclass
class SynthesisResult:
    choices: GammaDict
    cost: CostResult
    evaluated: int = 0
    log: List[str] = field(default_factory=list)

    def annotated(self, expr: L.Expr) -> L.Expr:
        return L.annotate(expr, {k: v.ds for k, v in self.choices.items()})


# ---------------------------------------------------------------------------
# Dependency order (Alg. 1 line 3)
# ---------------------------------------------------------------------------


def dependency_order(
    expr: L.Expr, log: Optional[List[str]] = None
) -> Tuple[str, ...]:
    """Topological order of dictionary symbols: if building/filling symbol B
    probes symbol A, then A precedes B.  Ties broken by program order.

    On a dependency cycle the remaining symbols fall back to program order;
    the cycle is recorded in ``log`` (surfaced through
    ``SynthesisResult.log``) so synthesis explains stay trustworthy."""
    syms = list(L.dict_symbols(expr))
    deps: Dict[str, set] = {s: set() for s in syms}

    def updated_dict(e: L.Expr) -> Optional[str]:
        d = e.dict  # type: ignore[attr-defined]
        return d.name if isinstance(d, L.Var) else None

    def looked_up(e: L.Expr) -> Iterable[str]:
        for n in L.walk(e):
            if isinstance(n, (L.DictLookup, L.HintedLookup)) and isinstance(
                n.dict, L.Var
            ):
                yield n.dict.name

    # For every update site of B, every dictionary looked up in the update's
    # enclosing statement is a dependency of B.
    def scan(e: L.Expr) -> None:
        for n in L.walk(e):
            if isinstance(n, (L.DictUpdate, L.HintedUpdate)):
                b = updated_dict(n)
                if b in deps:
                    for a in looked_up(n):
                        if a in deps and a != b:
                            deps[b].add(a)

    scan(expr)
    out: List[str] = []
    remaining = list(syms)
    while remaining:
        progress = False
        for s in list(remaining):
            if deps[s] <= set(out):
                out.append(s)
                remaining.remove(s)
                progress = True
        if not progress:  # cycle — fall back to program order
            cycle = {
                s: sorted(deps[s] - set(out)) for s in remaining
            }
            if log is not None:
                log.append(
                    "dependency cycle: "
                    + "; ".join(f"{s} <- {', '.join(d)}" for s, d in cycle.items())
                    + " — falling back to program order"
                )
            out.extend(remaining)
            break
    return tuple(out)


# ---------------------------------------------------------------------------
# Candidate enumeration per symbol
# ---------------------------------------------------------------------------


def _placeable_syms(
    expr: L.Expr,
    sigma: CardModel,
    delta: DictCostModel,
    net,
    sharded_rels: Optional[Tuple[str, ...]],
) -> Optional[set]:
    """Symbols whose distributed *placement* is a real degree of freedom:
    index/partition dictionaries (nested values — the Fig. 6a build side,
    probed downstream) that are built, transitively, from a sharded base
    relation.  Dictionaries built purely from replicated inputs stay
    replicated under the legalizer, so enumerating placements for them would
    only double the search and stamp meaningless labels on the choices."""
    if net is None or net.n_shards <= 1:
        return None
    base = infer_cost(expr, sigma, delta)
    return {
        name
        for name, meta in base.dict_meta.items()
        if meta.nested
        and (sharded_rels is None or meta.build_rels & set(sharded_rels))
    }


def _candidates_for(
    sym: str, expr: L.Expr, candidates: Sequence[str], placeable=None
) -> List[DictChoice]:
    """ds × hinted (× placement) variants.  ``hinted`` is only meaningful for
    sort-based implementations, and only when the program actually contains
    hinted sites for this symbol *or* the cost model is allowed to consider
    the merge form (the lowering can legalise hinted probes whenever the
    probe sequence is sorted — the `ordered` flag in Δ prices exactly that).
    Under a distributed cost realization, symbols in ``placeable``
    additionally enumerate their placement — broadcast-build vs
    co-partitioned — so Alg. 1 decides implementation and placement jointly.
    """
    out = []
    for ds in candidates:
        if ds.startswith("st"):
            out.append(DictChoice(ds, hinted=True))
            out.append(DictChoice(ds, hinted=False))
        else:
            out.append(DictChoice(ds))
    if placeable is not None and sym in placeable:
        out = [
            DictChoice(c.ds, c.hinted, placement)
            for c in out
            for placement in ("partition", "broadcast")
        ]
    return out


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def synthesize(
    expr: L.Expr,
    sigma: CardModel,
    delta: DictCostModel,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    net=None,
    sharded_rels: Optional[Tuple[str, ...]] = None,
) -> SynthesisResult:
    """Greedy Algorithm 1.  Pass ``net`` (a :class:`repro.core.cost.NetCostModel`)
    to cost the *distributed* realization — each candidate then also pays the
    Exchange the sharded executor would insert for its dictionary, so choices
    account for shuffle volume, not just local op costs."""
    log: List[str] = []
    order = dependency_order(expr, log=log)
    placeable = _placeable_syms(expr, sigma, delta, net, sharded_rels)
    gamma: GammaDict = {}
    evaluated = 0
    for sym in order:
        best: Optional[DictChoice] = None
        best_cost = float("inf")
        for choice in _candidates_for(sym, expr, candidates, placeable):
            trial = dict(gamma)
            trial[sym] = choice
            res = infer_cost(
                expr, sigma, delta, trial, net=net, sharded_rels=sharded_rels
            )
            evaluated += 1
            if res.total < best_cost:
                best_cost = res.total
                best = choice
        assert best is not None
        gamma[sym] = best
        log.append(f"{sym}: {best} ({best_cost*1e3:.3f} ms)")
    final = infer_cost(expr, sigma, delta, gamma, net=net, sharded_rels=sharded_rels)
    return SynthesisResult(choices=gamma, cost=final, evaluated=evaluated, log=log)


def synthesize_exhaustive(
    expr: L.Expr,
    sigma: CardModel,
    delta: DictCostModel,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    net=None,
    sharded_rels: Optional[Tuple[str, ...]] = None,
) -> SynthesisResult:
    """Exact search over the full cross product — exponential; tests only."""
    syms = L.dict_symbols(expr)
    placeable = _placeable_syms(expr, sigma, delta, net, sharded_rels)
    per_sym = [_candidates_for(s, expr, candidates, placeable) for s in syms]
    best: Optional[GammaDict] = None
    best_res: Optional[CostResult] = None
    evaluated = 0
    for combo in itertools.product(*per_sym):
        gamma = dict(zip(syms, combo))
        res = infer_cost(
            expr, sigma, delta, gamma, net=net, sharded_rels=sharded_rels
        )
        evaluated += 1
        if best_res is None or res.total < best_res.total:
            best_res, best = res, gamma
    assert best is not None and best_res is not None
    return SynthesisResult(choices=best, cost=best_res, evaluated=evaluated)
