"""Cardinality model Σ — the paper's §2.3 pluggable estimator.

The paper delegates cardinality estimation to "state-of-the-art" models and
treats Σ as an oracle with three queries (Fig. 8):

    Σ_card(e)  — cardinality of the dictionary produced by ``e``
    Σ_dist(e)  — number of distinct values of a key expression
    Σ_sel(e)   — selectivity of a condition

We implement the classic System-R–style uniform/independence estimator over
per-relation statistics (row count, per-column distinct counts and min/max,
plus which columns the relation is physically sorted on).  The estimator is
*pluggable*: anything with the same three methods can be swapped in
(``exec.stats.collect`` builds exact stats from data for the benchmarks).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from . import llql as L

# ---------------------------------------------------------------------------
# Statistics containers
# ---------------------------------------------------------------------------


@dataclass
class ColumnStats:
    distinct: float
    lo: float = 0.0
    hi: float = 1.0


@dataclass
class RelStats:
    rows: float
    columns: Dict[str, ColumnStats] = field(default_factory=dict)
    sorted_on: Tuple[str, ...] = ()  # physical order of the relation

    def col(self, name: str) -> ColumnStats:
        if name not in self.columns:
            # Unknown column: assume key-like (all-distinct) — conservative
            # for group-by cardinality, harmless for selectivity.
            self.columns[name] = ColumnStats(distinct=self.rows)
        return self.columns[name]


# ---------------------------------------------------------------------------
# Key-expression analysis: which relation columns feed a key expression?
# ---------------------------------------------------------------------------


def key_columns(e: L.Expr, loopvar: str) -> Tuple[str, ...]:
    """Columns of the loop variable's relation referenced by a key/grouping
    expression, e.g. ``r.key.K`` -> ("K",); records yield all fields."""
    cols = []

    def go(x: L.Expr) -> None:
        if isinstance(x, L.FieldAccess):
            base = x.rec
            if (
                isinstance(base, L.FieldAccess)
                and base.name == "key"
                and isinstance(base.rec, L.Var)
                and base.rec.name == loopvar
            ):
                cols.append(x.name)
                return
            if isinstance(base, L.Var) and base.name == loopvar and x.name == "key":
                cols.append("*")  # whole-row key
                return
        for c in x.children():
            go(c)

    go(e)
    return tuple(dict.fromkeys(cols))  # dedupe, keep order


# ---------------------------------------------------------------------------
# The Σ model
# ---------------------------------------------------------------------------


class CardModel:
    def __init__(self, rels: Dict[str, RelStats]):
        self.rels = dict(rels)
        # cardinalities for let-bound dictionary symbols, filled by the
        # annotation pass in core.cost (and overridable for tests)
        self.dict_card: Dict[str, float] = {}
        self.dict_key_dist: Dict[str, float] = {}

    # -- relations ---------------------------------------------------------
    def rel(self, name: str) -> RelStats:
        if name not in self.rels:
            raise KeyError(f"no statistics for relation {name!r}")
        return self.rels[name]

    def card_rel(self, name: str) -> float:
        return self.rel(name).rows

    # -- Σ_dist ------------------------------------------------------------
    def dist(self, rel: str, cols: Tuple[str, ...]) -> float:
        r = self.rel(rel)
        if not cols:
            return 1.0
        if "*" in cols:
            return r.rows
        d = 1.0
        for c in cols:
            d *= max(1.0, r.col(c).distinct)
        return min(d, r.rows)

    # -- Σ_sel -------------------------------------------------------------
    def sel(self, cond: L.Expr, loopvar: str, rel: str) -> float:
        """Uniformity/independence selectivity of a row predicate."""
        r = self.rel(rel)
        if isinstance(cond, L.BinOp):
            if cond.op in ("&&",):
                return self.sel(cond.lhs, loopvar, rel) * self.sel(
                    cond.rhs, loopvar, rel
                )
            if cond.op in ("||",):
                a = self.sel(cond.lhs, loopvar, rel)
                b = self.sel(cond.rhs, loopvar, rel)
                return min(1.0, a + b - a * b)
            cols = key_columns(cond, loopvar)
            konst = _const_of(cond)
            if cond.op in ("<", "<=", ">", ">=") and cols:
                if konst is not None:
                    cs = r.col(cols[0])
                    if cs.hi <= cs.lo:
                        return 0.5
                    frac = (float(konst) - cs.lo) / (cs.hi - cs.lo)
                    frac = min(1.0, max(0.0, frac))
                    return frac if cond.op in ("<", "<=") else 1.0 - frac
                if _has_param(cond):
                    # range predicate against a free Param: price at the
                    # midpoint of the column bounds, so one synthesis covers
                    # every binding (DESIGN.md §6) — the expected selectivity
                    # of a uniformly drawn threshold over [lo, hi]
                    return 0.5
            if cond.op == "==" and cols:
                return 1.0 / max(1.0, r.col(cols[0]).distinct)
            if cond.op == "!=" and cols:
                return 1.0 - 1.0 / max(1.0, r.col(cols[0]).distinct)
        if isinstance(cond, L.UnOp) and cond.op == "!":
            return 1.0 - self.sel(cond.operand, loopvar, rel)
        return 0.5  # unknown predicate: textbook default

    # -- orderedness -------------------------------------------------------
    def is_sorted_on(self, rel: str, cols: Tuple[str, ...]) -> bool:
        """Is the relation physically ordered by (a prefix covering) cols?"""
        r = self.rel(rel)
        if not cols or not r.sorted_on:
            return False
        return tuple(r.sorted_on[: len(cols)]) == tuple(cols)


def _const_of(e: L.BinOp) -> Optional[float]:
    for side in (e.rhs, e.lhs):
        if isinstance(side, L.Const) and isinstance(side.value, (int, float)):
            return float(side.value)
    return None


def _has_param(e: L.Expr) -> bool:
    return any(isinstance(n, L.Param) for n in L.walk(e))
