"""LLQL operator builders — the paper's Fig. 6 / Fig. 7 listings as programs.

Each builder returns an ``llql.Expr`` tree in exactly the shape of the paper's
listings, with the dictionary annotations left open (``ds=None``) unless the
caller fixes them — synthesis (Alg. 1) fills them in.

Row-level expressions (predicates, keys, aggregates) are supplied as Python
callables that take the loop variable *expression* and return an LLQL
expression, e.g. ``lambda r: r.key.get("K")`` for ``part(r.key)``.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

from . import llql as L
from .llql import (
    BinOp,
    Const,
    DictIter,
    DictLookup,
    DictNew,
    DictUpdate,
    Expr,
    For,
    HintedLookup,
    HintedUpdate,
    If,
    Input,
    Let,
    Noop,
    RecordCtor,
    RefAdd,
    RefNew,
    Seq,
    Var,
    let,
    seq,
)

RowFn = Callable[[Expr], Expr]


def _rec(fields: Sequence[Tuple[str, Expr]]) -> RecordCtor:
    return RecordCtor(tuple(fields))


# ---------------------------------------------------------------------------
# §3.3 basic operators
# ---------------------------------------------------------------------------


def selection(rel: str, pred: RowFn, out: str = "sel", ds: Optional[str] = None) -> Expr:
    """§3.3.1:  for r in R: if p(r.key): sel(r.key) += r.val"""
    r = Var("r")
    return let(
        out,
        DictNew(ds),
        seq(
            For(
                "r",
                Input(rel),
                If(pred(r), DictUpdate(Var(out), r.key, r.val)),
            ),
            Var(out),
        ),
    )


def projection(rel: str, proj: RowFn, out: str = "proj", ds: Optional[str] = None) -> Expr:
    """§3.3.2:  for r in R: proj(f(r.key)) += r.val"""
    r = Var("r")
    return let(
        out,
        DictNew(ds),
        seq(
            For("r", Input(rel), DictUpdate(Var(out), proj(r), r.val)),
            Var(out),
        ),
    )


def nested_loop_join(
    rel_r: str,
    rel_s: str,
    cond: Callable[[Expr, Expr], Expr],
    out_key: Callable[[Expr, Expr], Expr],
    out: str = "join",
    ds: Optional[str] = None,
) -> Expr:
    """§3.3.3 nested-loop join."""
    r, s = Var("r"), Var("s")
    return let(
        out,
        DictNew(ds),
        seq(
            For(
                "r",
                Input(rel_r),
                For(
                    "s",
                    Input(rel_s),
                    If(
                        cond(r, s),
                        DictUpdate(Var(out), out_key(r, s), r.val * s.val),
                    ),
                ),
            ),
            Var(out),
        ),
    )


def scalar_aggregate(
    rel: str, aggfn: RowFn, agg_type: L.Type = L.DOUBLE, pred: Optional[RowFn] = None
) -> Expr:
    """§3.3.4:  agg += aggFun(r.key) * r.val"""
    r = Var("r")
    body: Expr = RefAdd(Var("agg"), aggfn(r) * r.val)
    if pred is not None:
        body = If(pred(r), body)
    return let(
        "agg",
        RefNew(agg_type),
        seq(For("r", Input(rel), body), Var("agg")),
    )


def groupby(
    rel: str,
    grp: RowFn,
    aggfn: RowFn,
    out: str = "Agg",
    ds: Optional[str] = None,
    hinted: bool = False,
    pred: Optional[RowFn] = None,
) -> Expr:
    """§3.6 / Fig. 6c-6d group-by aggregate (hinted variant = Fig. 6d)."""
    r = Var("r")
    if hinted:
        upd: Expr = HintedUpdate(Var(out), Var("it"), grp(r), aggfn(r) * r.val)
    else:
        upd = DictUpdate(Var(out), grp(r), aggfn(r) * r.val)
    if pred is not None:
        upd = If(pred(r), upd)
    loop = For("r", Input(rel), upd)
    inner = seq(loop, Var(out))
    if hinted:
        inner = let("it", DictIter(Var(out)), inner)
    return let(out, DictNew(ds), inner)


# ---------------------------------------------------------------------------
# §3.4 partitioned joins (Fig. 6a / 6b)
# ---------------------------------------------------------------------------


def partitioned_join(
    rel_r: str,
    rel_s: str,
    part_r: RowFn,
    part_s: RowFn,
    out_key: Callable[[Expr, Expr], Expr],
    build: str = "Sd",
    out: str = "RS",
    build_ds: Optional[str] = None,
    out_ds: Optional[str] = None,
    hinted_lookup: bool = False,
    hinted_build: bool = False,
    pred_r: Optional[RowFn] = None,
    pred_s: Optional[RowFn] = None,
) -> Expr:
    """Fig. 6a (hash join) / Fig. 6b (sort-merge join, hinted).

    Build ``build`` as a partition dictionary  part(s.key) -> {{s.key->s.val}}
    then probe with R, emitting ``out_key(r, s) -> r.val * s.val``.
    """
    r, s = Var("r"), Var("s")

    # -- build phase
    inner_single = DictNew(None, s.key, s.val)  # {{ s.key -> s.val }}
    if hinted_build:
        bupd: Expr = HintedUpdate(Var(build), Var("it_b"), part_s(s), inner_single)
    else:
        bupd = DictUpdate(Var(build), part_s(s), inner_single)
    if pred_s is not None:
        bupd = If(pred_s(s), bupd)
    build_loop = For("s", Input(rel_s), bupd)

    # -- probe phase
    if hinted_lookup:
        probe_src: Expr = HintedLookup(Var(build), Var("it"), Var("rkey"))
    else:
        probe_src = DictLookup(Var(build), Var("rkey"))
    probe_body: Expr = Let(
        "rkey",
        part_r(r),
        For(
            "s",
            probe_src,
            DictUpdate(Var(out), out_key(r, s), r.val * s.val),
        ),
    )
    if pred_r is not None:
        probe_body = If(pred_r(r), probe_body)
    probe_loop = For("r", Input(rel_r), probe_body)

    probe_part: Expr = seq(probe_loop, Var(out))
    if hinted_lookup:
        probe_part = let("it", DictIter(Var(build)), probe_part)
    body: Expr = let(out, DictNew(out_ds), probe_part)
    build_part: Expr = seq(build_loop, body)
    if hinted_build:
        build_part = let("it_b", DictIter(Var(build)), build_part)
    return let(build, DictNew(build_ds), build_part)


def hash_join(*args, **kw) -> Expr:
    kw.setdefault("build_ds", "ht_linear")
    return partitioned_join(*args, **kw)


def sort_merge_join(*args, **kw) -> Expr:
    kw.setdefault("build_ds", "st_sorted")
    kw.setdefault("hinted_lookup", True)
    return partitioned_join(*args, **kw)


def index_nested_loop_join(
    rel_r: str,
    index: str,
    part_r: RowFn,
    out_key: Callable[[Expr, Expr], Expr],
    out: str = "RS",
    out_ds: Optional[str] = None,
    pred_r: Optional[RowFn] = None,
) -> Expr:
    """§3.5 — probe a pre-built index (an input dictionary) directly."""
    r, s = Var("r"), Var("s")
    probe_body: Expr = For(
        "s",
        DictLookup(Input(index), part_r(r)),
        DictUpdate(Var(out), out_key(r, s), r.val * s.val),
    )
    if pred_r is not None:
        probe_body = If(pred_r(r), probe_body)
    return let(
        out,
        DictNew(out_ds),
        seq(For("r", Input(rel_r), probe_body), Var(out)),
    )


# ---------------------------------------------------------------------------
# §3.7 groupjoin (Fig. 6e / 6f) — the paper's running example shape
# ---------------------------------------------------------------------------


def groupjoin(
    rel_r: str,
    rel_s: str,
    key_r: RowFn,
    key_s: RowFn,
    g: RowFn,
    f: RowFn,
    build: str = "Sd",
    out: str = "Agg",
    build_ds: Optional[str] = None,
    out_ds: Optional[str] = None,
    hinted: bool = False,
    pred_s: Optional[RowFn] = None,
    pred_r: Optional[RowFn] = None,
) -> Expr:
    """Fig. 6e/6f: build partial aggregate of S on A, then for each r of R
    combine ``f(r) * g_sum(s)`` into Agg keyed by A.

        for s in S:  Sd(s.key.A) += g(s)
        for r in R:  for gs in Sd(r.key.A):  Agg(r.key.A) += f(r) * gs.val
    """
    r, s = Var("r"), Var("s")
    bupd: Expr = (
        HintedUpdate(Var(build), Var("it1"), key_s(s), g(s) * s.val)
        if hinted
        else DictUpdate(Var(build), key_s(s), g(s) * s.val)
    )
    if pred_s is not None:
        bupd = If(pred_s(s), bupd)
    build_loop = For("s", Input(rel_s), bupd)

    probe_src: Expr = (
        HintedLookup(Var(build), Var("it1"), key_r(r))
        if hinted
        else DictLookup(Var(build), key_r(r))
    )
    # Sd maps A -> partial aggregate (scalar); lookup yields the partial sum,
    # missing keys annihilate the product (no match -> no contribution).
    agg_upd: Expr = (
        HintedUpdate(Var(out), Var("it2"), key_r(r), f(r) * r.val * probe_src)
        if hinted
        else DictUpdate(Var(out), key_r(r), f(r) * r.val * probe_src)
    )
    if pred_r is not None:
        agg_upd = If(pred_r(r), agg_upd)
    probe_loop = For("r", Input(rel_r), agg_upd)

    inner: Expr = seq(build_loop, probe_loop, Var(out))
    if hinted:
        inner = let("it1", DictIter(Var(build)), let("it2", DictIter(Var(out)), inner))
    return let(build, DictNew(build_ds), let(out, DictNew(out_ds), inner))


def running_example(
    rel_o: str = "O",
    rel_l: str = "L",
    date: float = 0.5,
    ds: Optional[str] = None,
) -> Expr:
    """The paper's §1 motivating query (simplified TPC-H Q3) as a groupjoin:

        init Dict
        for o in O:   if o.T < DATE:  Dict(o.K) = 0         (build: mark keys)
        for l in L:   if Dict.contains(l.K): Dict(l.K) += l.P * l.D
    """
    o, l = Var("o"), Var("l")
    build_loop = For(
        "o",
        Input(rel_o),
        If(
            o.key.get("T") < Const(date, L.DOUBLE),
            DictUpdate(Var("D"), o.key.get("K"), Const(0.0, L.DOUBLE)),
        ),
    )
    probe_loop = For(
        "l",
        Input(rel_l),
        DictUpdate(
            Var("D"),
            l.key.get("K"),
            l.key.get("P") * l.key.get("D") * l.val * DictLookup(Var("Dmark"), l.key.get("K")),
        ),
    )
    # NOTE: the paper uses `contains` — we express it as multiplying by a
    # 0/1-marker dictionary Dmark so the program stays in the Fig. 5 grammar.
    # The canonical contains-style form is what `groupjoin_contains` builds.
    del probe_loop
    return groupjoin_contains(rel_o, rel_l, date=date, ds=ds)


def groupjoin_contains(
    rel_o: str = "O",
    rel_l: str = "L",
    date: float = 0.5,
    ds: Optional[str] = None,
    out: str = "D",
) -> Expr:
    """Running example in contains-guard form:

        for o in O: if o.T < DATE: D(o.K) += 0
        for l in L: for _m in D(l.K):  D(l.K) += l.P * l.D * l.val
    """
    o, l = Var("o"), Var("l")
    build_loop = For(
        "o",
        Input(rel_o),
        If(
            o.key.get("T") < Const(date, L.DOUBLE),
            DictUpdate(Var(out), o.key.get("K"), Const(0.0, L.DOUBLE)),
        ),
    )
    # `for m in D(l.K)` over a scalar value is not iterable; the paper's
    # `contains` guard is expressed by probing the dictionary and multiplying
    # the increment by 1 when present.  We model contains as a lookup whose
    # MISSING annihilates the update (interp: MISSING * x = MISSING, and
    # update_add with MISSING value is a no-op via guard below).
    probe_loop = For(
        "l",
        Input(rel_l),
        If(
            BinOp("!=", DictLookup(Var(out), l.key.get("K")), Const(None, L.DOUBLE)),
            DictUpdate(
                Var(out),
                l.key.get("K"),
                l.key.get("P") * l.key.get("D") * l.val,
            ),
        ),
    )
    return let(out, DictNew(ds), seq(build_loop, probe_loop, Var(out)))


# ---------------------------------------------------------------------------
# §3.8 in-DB ML: covariance matrix over a join (Fig. 7a → 7d)
# ---------------------------------------------------------------------------
# Schema: S(s, i, u), R(s, c); Q = S ⋈ R on s; covariance terms over F={i, c}.


def covar_naive() -> Expr:
    """Fig. 7a — materialize Q = S ⋈ R then aggregate i·i, i·c, c·c."""
    r, s, x = Var("r"), Var("s"), Var("x")
    cov_t = L.RecordT((("i_i", L.DOUBLE), ("i_c", L.DOUBLE), ("c_c", L.DOUBLE)))
    prog = let(
        "Rp",
        DictNew(None),
        seq(
            For(
                "r",
                Input("R"),
                DictUpdate(
                    Var("Rp"),
                    r.key.get("s"),
                    DictNew(None, r.key, r.val),
                ),
            ),
            let(
                "Q",
                DictNew(None),
                seq(
                    For(
                        "s",
                        Input("S"),
                        For(
                            "r",
                            DictLookup(Var("Rp"), s.key.get("s")),
                            DictUpdate(
                                Var("Q"),
                                _rec(
                                    [("i", s.key.get("i")), ("c", r.key.get("c"))]
                                ),
                                r.val * s.val,
                            ),
                        ),
                    ),
                    let(
                        "Covar",
                        RefNew(cov_t),
                        seq(
                            For(
                                "x",
                                Var("Q"),
                                RefAdd(
                                    Var("Covar"),
                                    _rec(
                                        [
                                            ("i_i", x.key.get("i") * x.key.get("i") * x.val),
                                            ("i_c", x.key.get("i") * x.key.get("c") * x.val),
                                            ("c_c", x.key.get("c") * x.key.get("c") * x.val),
                                        ]
                                    ),
                                ),
                            ),
                            Var("Covar"),
                        ),
                    ),
                ),
            ),
        ),
    )
    return prog


def covar_interleaved(ragg_ds: Optional[str] = None) -> Expr:
    """Fig. 7b — push partial aggregates of R (m, c, c_c) below the join."""
    r, s = Var("r"), Var("s")
    cov_t = L.RecordT((("i_i", L.DOUBLE), ("i_c", L.DOUBLE), ("c_c", L.DOUBLE)))
    ragg_loop = For(
        "r",
        Input("R"),
        DictUpdate(
            Var("Ragg"),
            r.key.get("s"),
            _rec(
                [
                    ("m", r.val),
                    ("c", r.key.get("c") * r.val),
                    ("c_c", r.key.get("c") * r.key.get("c") * r.val),
                ]
            ),
        ),
    )
    s_loop = For(
        "s",
        Input("S"),
        Let(
            "ra",
            DictLookup(Var("Ragg"), s.key.get("s")),
            RefAdd(
                Var("Covar"),
                _rec(
                    [
                        (
                            "i_i",
                            s.key.get("i") * s.key.get("i") * s.val * Var("ra").get("m"),
                        ),
                        ("i_c", s.key.get("i") * s.val * Var("ra").get("c")),
                        ("c_c", s.val * Var("ra").get("c_c")),
                    ]
                ),
            ),
        ),
    )
    return let(
        "Ragg",
        DictNew(ragg_ds),
        seq(
            ragg_loop,
            let("Covar", RefNew(cov_t), seq(s_loop, Var("Covar"))),
        ),
    )


def covar_factorized(ragg_ds: Optional[str] = None, hinted: bool = False) -> Expr:
    """Fig. 7d — trie-indexed S (input ``Strie``: s -> {{ i -> mult }}) with
    inner partial aggregates hoisted out (factorization + LICM)."""
    st, s = Var("st"), Var("s")
    cov_t = L.RecordT((("i_i", L.DOUBLE), ("i_c", L.DOUBLE), ("c_c", L.DOUBLE)))
    sagg_t = L.RecordT((("i_i", L.DOUBLE), ("i", L.DOUBLE), ("m", L.DOUBLE)))
    r = Var("ra")
    ragg_loop = For(
        "r",
        Input("R"),
        DictUpdate(
            Var("Ragg"),
            Var("r").key.get("s"),
            _rec(
                [
                    ("m", Var("r").val),
                    ("c", Var("r").key.get("c") * Var("r").val),
                    (
                        "c_c",
                        Var("r").key.get("c") * Var("r").key.get("c") * Var("r").val,
                    ),
                ]
            ),
        ),
    )
    lookup: Expr = (
        HintedLookup(Var("Ragg"), Var("it"), st.key) if hinted else DictLookup(Var("Ragg"), st.key)
    )
    inner = Let(
        "ra",
        lookup,
        Let(
            "sagg",
            RefNew(sagg_t),
            seq(
                For(
                    "s",
                    st.val,
                    RefAdd(
                        Var("sagg"),
                        _rec(
                            [
                                ("i_i", s.key * s.key * s.val),
                                ("i", s.key * s.val),
                                ("m", s.val),
                            ]
                        ),
                    ),
                ),
                RefAdd(
                    Var("Covar"),
                    _rec(
                        [
                            ("i_i", Var("sagg").get("i_i") * r.get("m")),
                            ("i_c", Var("sagg").get("i") * r.get("c")),
                            ("c_c", Var("sagg").get("m") * r.get("c_c")),
                        ]
                    ),
                ),
            ),
        ),
    )
    trie_loop = For("st", Input("Strie"), inner)
    body: Expr = let("Covar", RefNew(cov_t), seq(trie_loop, Var("Covar")))
    if hinted:
        body = let("it", DictIter(Var("Ragg")), body)
    return let("Ragg", DictNew(ragg_ds), seq(ragg_loop, body))


def covar_semiring_terms(
    ragg_ds: Optional[str] = None, with_b: bool = False
) -> List[Tuple[str, Expr]]:
    """§3.8 on the semiring path: the covariance matrix as independent
    sum-of-product programs whose S (and R) scans merge into ONE shared
    pass (``plan.merge_shared_scans`` — DESIGN.md §9).

    Each normal-equation term is its own tiny LLQL program ending in a
    scalar ``SemiringAgg("sum_product", ...)`` reduce:

        i_i = Σ_S i·i·s.val
        i_c = Σ_S i·Ragg[s].c·s.val       Ragg[s].c   = Σ_R c·r.val
        c_c = Σ_S Ragg[s].c_c·s.val       Ragg[s].c_c = Σ_R c·c·r.val

    With ``with_b`` the right-hand side rides the same scans
    (b_i = Σ_S i·u·s.val, b_c = Σ_S u·Ragg[s].c·s.val), so the whole
    linear regression is one pass over S plus one pass over R.  Returns
    ``[(term name, program)]`` in a stable order.
    """
    s, r, ra = Var("s"), Var("r"), Var("ra")

    def sp(*xs: Expr) -> Expr:
        return L.SemiringAgg("sum_product", tuple(xs))

    def ref_t(name: str) -> L.RecordT:
        return L.RecordT(((name, L.DOUBLE),))

    def s_only(name: str, payload: Expr) -> Expr:
        return let(
            "Covar",
            RefNew(ref_t(name)),
            seq(
                For("s", Input("S"), RefAdd(Var("Covar"), _rec([(name, payload)]))),
                Var("Covar"),
            ),
        )

    def with_ragg(name: str, lane: str, lane_payload: Expr, payload: Expr) -> Expr:
        ragg_loop = For(
            "r",
            Input("R"),
            DictUpdate(Var("Ragg"), r.key.get("s"), _rec([(lane, lane_payload)])),
        )
        s_loop = For(
            "s",
            Input("S"),
            Let(
                "ra",
                DictLookup(Var("Ragg"), s.key.get("s")),
                RefAdd(Var("Covar"), _rec([(name, payload)])),
            ),
        )
        return let(
            "Ragg",
            DictNew(ragg_ds),
            seq(
                ragg_loop,
                let("Covar", RefNew(ref_t(name)), seq(s_loop, Var("Covar"))),
            ),
        )

    i, u, sval = s.key.get("i"), s.key.get("u"), s.val
    c, rval = r.key.get("c"), r.val
    terms = [
        ("i_i", s_only("i_i", sp(i, i, sval))),
        ("i_c", with_ragg("i_c", "c", sp(c, rval), sp(i, sval, ra.get("c")))),
        ("c_c", with_ragg("c_c", "c_c", sp(c, c, rval), sp(sval, ra.get("c_c")))),
    ]
    if with_b:
        terms += [
            ("b_i", s_only("b_i", sp(i, u, sval))),
            ("b_c", with_ragg("b_c", "c", sp(c, rval), sp(u, sval, ra.get("c")))),
        ]
    return terms
