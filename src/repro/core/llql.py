"""LLQL — the paper's low-level, dictionary-based intermediate language (Fig. 5).

The IR is a small, typed, expression-oriented AST.  Dictionaries are the core
data type: relations are dictionaries from row-records to multiplicities (bag
semantics), join/aggregate state is a dictionary, and trie indices are nested
dictionaries.  The data-structure choice for every dictionary is an annotation
(``@ht`` / ``@st`` families) on its constructor — the whole point of the paper
is that this annotation is chosen by cost-based synthesis, not by the engine
developer.

Grammar coverage (paper Fig. 5):

    e ::= e ; e | () | let x = e in e | if(e) then e else e
        | { a = e, ... } | e.a | e bop e | uop e | n | r | false | true | "s"
        | ref(T) | e += e
        | @ds {{ e -> e }} | for (x <- e) e
        | e(e) += e | e(e) | e.iter | e<it>(e) += e | e<it>(e)

    T ::= @ds {{ T -> T }} | int | double | bool | string | { a: T, ... }

    @ds ::= @ht | @st | ... (any registered dictionary implementation id)

Design notes
------------
* Nodes are frozen dataclasses → hashable, structurally comparable, safe to
  use as pattern-matching subjects in the lowerer.
* ``DictNew.ds`` may be ``None`` — "unannotated"; synthesis (Alg. 1) fills it.
* Hinted ops carry the *name* of the iterator binding (``Let`` of ``DictIter``)
  exactly like the paper's ``dict<it>(k)`` surface syntax.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional, Tuple

# ---------------------------------------------------------------------------
# Types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Type:
    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.__class__.__name__


@dataclass(frozen=True)
class ScalarT(Type):
    kind: str  # "int" | "double" | "bool" | "string"

    def __str__(self) -> str:
        return self.kind


INT = ScalarT("int")
DOUBLE = ScalarT("double")
BOOL = ScalarT("bool")
STRING = ScalarT("string")


@dataclass(frozen=True)
class RecordT(Type):
    fields: Tuple[Tuple[str, Type], ...]

    def __str__(self) -> str:
        inner = ", ".join(f"{a}: {t}" for a, t in self.fields)
        return "{" + inner + "}"

    def field_type(self, name: str) -> Type:
        for a, t in self.fields:
            if a == name:
                return t
        raise KeyError(name)


@dataclass(frozen=True)
class DictT(Type):
    key: Type
    val: Type
    ds: Optional[str] = None  # implementation annotation, None = unchosen

    def __str__(self) -> str:
        pre = f"@{self.ds} " if self.ds else ""
        return pre + "{{" + f"{self.key} -> {self.val}" + "}}"


@dataclass(frozen=True)
class RefT(Type):
    inner: Type

    def __str__(self) -> str:
        return f"ref({self.inner})"


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    def children(self) -> Tuple["Expr", ...]:
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Expr):
                out.append(v)
            elif isinstance(v, tuple):
                out.extend(x for x in v if isinstance(x, Expr))
            elif isinstance(v, dict):  # pragma: no cover - no dict fields today
                out.extend(x for x in v.values() if isinstance(x, Expr))
        return tuple(out)

    # Sugar so programs read like the paper.
    def __add__(self, other: "Expr") -> "Expr":
        return BinOp("+", self, _e(other))

    def __sub__(self, other: "Expr") -> "Expr":
        return BinOp("-", self, _e(other))

    def __mul__(self, other: "Expr") -> "Expr":
        return BinOp("*", self, _e(other))

    def __mod__(self, other: "Expr") -> "Expr":
        return BinOp("%", self, _e(other))

    def __lt__(self, other: "Expr") -> "Expr":
        return BinOp("<", self, _e(other))

    def __le__(self, other: "Expr") -> "Expr":
        return BinOp("<=", self, _e(other))

    def __gt__(self, other: "Expr") -> "Expr":
        return BinOp(">", self, _e(other))

    def __ge__(self, other: "Expr") -> "Expr":
        return BinOp(">=", self, _e(other))

    def eq(self, other: "Expr") -> "Expr":
        return BinOp("==", self, _e(other))

    def ne(self, other: "Expr") -> "Expr":
        return BinOp("!=", self, _e(other))

    def get(self, name: str) -> "Expr":
        return FieldAccess(self, name)

    # r.key / r.val sugar used everywhere in the paper's listings
    @property
    def key(self) -> "Expr":
        return FieldAccess(self, "key")

    @property
    def val(self) -> "Expr":
        return FieldAccess(self, "val")


def _e(x: Any) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, bool):
        return Const(x, BOOL)
    if isinstance(x, int):
        return Const(x, INT)
    if isinstance(x, float):
        return Const(x, DOUBLE)
    if isinstance(x, str):
        return Const(x, STRING)
    raise TypeError(f"cannot lift {x!r} into LLQL")


@dataclass(frozen=True)
class Const(Expr):
    value: Any
    type: Type


@dataclass(frozen=True)
class Param(Expr):
    """A free query parameter (``?name``): a scalar whose value is supplied at
    execution time, not synthesis time.  Parameterization is what makes the
    compile-once/execute-many split possible — synthesis and lowering see one
    program per query *shape*, and ``Plan.bind`` substitutes fresh values
    without re-synthesizing or re-tracing (DESIGN.md §6)."""

    name: str
    type: Type


@dataclass(frozen=True)
class Var(Expr):
    name: str


@dataclass(frozen=True)
class Noop(Expr):
    pass


@dataclass(frozen=True)
class Seq(Expr):
    first: Expr
    second: Expr


@dataclass(frozen=True)
class Let(Expr):
    name: str
    value: Expr
    body: Expr


@dataclass(frozen=True)
class If(Expr):
    cond: Expr
    then: Expr
    els: Expr = field(default_factory=Noop)


@dataclass(frozen=True)
class RecordCtor(Expr):
    fields: Tuple[Tuple[str, Expr], ...]


@dataclass(frozen=True)
class FieldAccess(Expr):
    rec: Expr
    name: str


@dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * / && || == != < <= > >= min max
    lhs: Expr
    rhs: Expr


@dataclass(frozen=True)
class UnOp(Expr):
    op: str  # ! -
    operand: Expr


@dataclass(frozen=True)
class RefNew(Expr):
    """``ref(T)`` — a mutable scalar/record accumulator, initialised to zero."""

    type: Type


@dataclass(frozen=True)
class RefAdd(Expr):
    """``x += e`` where x binds a ``RefNew``."""

    ref: Expr
    value: Expr


@dataclass(frozen=True)
class DictNew(Expr):
    """``@ds {{ k -> v }}`` or the empty ``@ds {{ }}``.

    ``ds`` None means the implementation is left to synthesis.
    """

    ds: Optional[str] = None
    key: Optional[Expr] = None
    val: Optional[Expr] = None
    type: Optional[DictT] = None  # optional declared type


@dataclass(frozen=True)
class For(Expr):
    """``for (x <- e) body`` — iterate key/value pairs of a dictionary."""

    var: str
    source: Expr
    body: Expr


@dataclass(frozen=True)
class DictUpdate(Expr):
    """``d(k) += v``"""

    dict: Expr
    keyexpr: Expr
    value: Expr


@dataclass(frozen=True)
class DictLookup(Expr):
    """``d(k)``"""

    dict: Expr
    keyexpr: Expr


@dataclass(frozen=True)
class DictIter(Expr):
    """``d.iter``"""

    dict: Expr


@dataclass(frozen=True)
class HintedUpdate(Expr):
    """``d<it>(k) += v``"""

    dict: Expr
    hint: Expr
    keyexpr: Expr
    value: Expr


@dataclass(frozen=True)
class HintedLookup(Expr):
    """``d<it>(k)``"""

    dict: Expr
    hint: Expr
    keyexpr: Expr


# Semiring aggregate lanes (arXiv 2103.06376): LLQL dictionaries are semiring
# dictionaries — the value record of an aggregation dictionary (or a scalar
# ref record) is a product of semiring lanes, each combining row
# contributions under its own monoid.  ``sum``/``count``/``sum_product``
# combine additively (the numeric semiring the engine always had);
# ``min``/``max`` combine under the tropical semirings.  A lane's
# *contribution* is the per-row expression fed to the combine.

SEMIRING_OPS = ("sum", "count", "min", "max", "sum_product")

# lane combine monoid per semiring op (what the dictionary build applies)
SEMIRING_COMBINE = {
    "sum": "sum",
    "count": "sum",
    "sum_product": "sum",
    "min": "min",
    "max": "max",
}


@dataclass(frozen=True)
class SemiringAgg(Expr):
    """One semiring aggregate lane: ``op`` over a ``payload`` vector.

    Used as a field value inside the ``RecordCtor`` of a ``DictUpdate`` /
    ``RefAdd`` — the surface form of the paper's aggregation dictionaries,
    generalized beyond sums.  ``count`` takes no payload; ``sum``/``min``/
    ``max`` take one expression; ``sum_product`` multiplies its whole
    payload vector per row (the in-DB ML covariance entries)."""

    op: str
    payload: Tuple[Expr, ...] = ()

    def __post_init__(self) -> None:
        if self.op not in SEMIRING_OPS:
            raise ValueError(f"unknown semiring op {self.op!r}")
        if self.op == "count":
            if self.payload:
                raise ValueError("count takes no payload")
        elif not self.payload:
            raise ValueError(f"{self.op} needs a payload")
        elif self.op != "sum_product" and len(self.payload) != 1:
            raise ValueError(f"{self.op} takes exactly one payload expression")

    @property
    def combine(self) -> str:
        """The lane's combine monoid: "sum" | "min" | "max"."""
        return SEMIRING_COMBINE[self.op]

    def contribution(self) -> Expr:
        """The per-row contribution expression this lane feeds its combine."""
        if self.op == "count":
            return Const(1.0, DOUBLE)
        if self.op == "sum_product":
            out = self.payload[0]
            for x in self.payload[1:]:
                out = BinOp("*", out, x)
            return out
        return self.payload[0]


# A free relation/dictionary input to the program (a named table).
@dataclass(frozen=True)
class Input(Expr):
    name: str
    type: Optional[DictT] = None


# ---------------------------------------------------------------------------
# Traversal / rewriting helpers
# ---------------------------------------------------------------------------


def walk(e: Expr) -> Iterator[Expr]:
    """Pre-order traversal."""
    stack = [e]
    while stack:
        n = stack.pop()
        yield n
        stack.extend(reversed(n.children()))


def rewrite(e: Expr, fn) -> Expr:
    """Bottom-up rewrite: ``fn`` sees each node after its children were
    rewritten; returning the node unchanged keeps it."""

    def go(n: Expr) -> Expr:
        reps = {}
        for f in dataclasses.fields(n):
            v = getattr(n, f.name)
            if isinstance(v, Expr):
                nv = go(v)
                if nv is not v:
                    reps[f.name] = nv
            elif isinstance(v, tuple) and v and isinstance(v[0], tuple):
                # tuple of (name, Expr) pairs (RecordCtor.fields)
                nt = tuple(
                    (a, go(x)) if isinstance(x, Expr) else (a, x) for a, x in v
                )
                if nt != v:
                    reps[f.name] = nt
            elif isinstance(v, tuple) and v and isinstance(v[0], Expr):
                # plain tuple of Exprs (SemiringAgg.payload)
                nt = tuple(go(x) if isinstance(x, Expr) else x for x in v)
                if nt != v:
                    reps[f.name] = nt
        if reps:
            n = dataclasses.replace(n, **reps)
        return fn(n)

    return go(e)


def dict_symbols(e: Expr) -> Tuple[str, ...]:
    """Names of all ``let``-bound dictionaries constructed in the program, in
    program order (Alg. 1 line 2: ExtractDictSymbols)."""
    out = []
    for n in walk(e):
        if isinstance(n, Let) and isinstance(n.value, DictNew):
            out.append(n.name)
    return tuple(out)


def params_of(e: Expr) -> Tuple["Param", ...]:
    """Free parameters of a program, in first-occurrence order, deduped by
    name.  A name appearing with two different types is a program error."""
    seen: dict = {}
    for n in walk(e):
        if isinstance(n, Param):
            prev = seen.get(n.name)
            if prev is not None and prev != n:
                raise TypeError(
                    f"parameter {n.name!r} declared with conflicting types"
                )
            seen.setdefault(n.name, n)
    return tuple(seen.values())


def bind_params(e: Expr, bindings: dict) -> Expr:
    """Substitute ``Param`` nodes with ``Const`` values — the const-baked
    program a non-parameterized pipeline would have written.  Used by tests
    to check bound plans against the one-program-per-value path; the fast
    path never rewrites (``Plan.bind`` passes values at runtime)."""

    def fn(n: Expr) -> Expr:
        if isinstance(n, Param):
            if n.name not in bindings:
                raise KeyError(f"unbound parameter {n.name!r}")
            return Const(bindings[n.name], n.type)
        return n

    return rewrite(e, fn)


def annotate(e: Expr, choices: dict) -> Expr:
    """Replace the ``@ds`` annotation of each let-bound dictionary symbol with
    the synthesis choice (Alg. 1 line 9: ChooseDictDS)."""

    def fn(n: Expr) -> Expr:
        if isinstance(n, Let) and isinstance(n.value, DictNew) and n.name in choices:
            return dataclasses.replace(
                n, value=dataclasses.replace(n.value, ds=choices[n.name])
            )
        return n

    return rewrite(e, fn)


# ---------------------------------------------------------------------------
# Pretty printer (paper surface syntax)
# ---------------------------------------------------------------------------


def pretty(e: Expr, indent: int = 0) -> str:
    pad = "  " * indent

    def p(x: Expr) -> str:
        return pretty(x, indent)

    if isinstance(e, Const):
        return repr(e.value)
    if isinstance(e, Param):
        return f"?{e.name}"
    if isinstance(e, Var):
        return e.name
    if isinstance(e, Input):
        return e.name
    if isinstance(e, Noop):
        return "()"
    if isinstance(e, Seq):
        return f"{p(e.first)} ;\n{pad}{p(e.second)}"
    if isinstance(e, Let):
        return (
            f"let {e.name} = {p(e.value)} in\n{pad}{pretty(e.body, indent)}"
        )
    if isinstance(e, If):
        if isinstance(e.els, Noop):
            return f"if({p(e.cond)}) then {p(e.then)} else ()"
        return f"if({p(e.cond)}) then {p(e.then)} else {p(e.els)}"
    if isinstance(e, RecordCtor):
        inner = ", ".join(f"{a} = {p(x)}" for a, x in e.fields)
        return "{ " + inner + " }"
    if isinstance(e, FieldAccess):
        return f"{p(e.rec)}.{e.name}"
    if isinstance(e, BinOp):
        return f"({p(e.lhs)} {e.op} {p(e.rhs)})"
    if isinstance(e, UnOp):
        return f"({e.op}{p(e.operand)})"
    if isinstance(e, RefNew):
        return f"ref({e.type})"
    if isinstance(e, RefAdd):
        return f"{p(e.ref)} += {p(e.value)}"
    if isinstance(e, DictNew):
        ann = f"@{e.ds} " if e.ds else ""
        if e.key is None:
            return ann + "{{ }}"
        return ann + "{{ " + f"{p(e.key)} -> {p(e.val)}" + " }}"
    if isinstance(e, For):
        return (
            f"for({e.var} <- {p(e.source)}) {{\n"
            + "  " * (indent + 1)
            + pretty(e.body, indent + 1)
            + f"\n{pad}}}"
        )
    if isinstance(e, DictUpdate):
        return f"{p(e.dict)}({p(e.keyexpr)}) += {p(e.value)}"
    if isinstance(e, DictLookup):
        return f"{p(e.dict)}({p(e.keyexpr)})"
    if isinstance(e, DictIter):
        return f"{p(e.dict)}.iter"
    if isinstance(e, HintedUpdate):
        return f"{p(e.dict)}<{p(e.hint)}>({p(e.keyexpr)}) += {p(e.value)}"
    if isinstance(e, HintedLookup):
        return f"{p(e.dict)}<{p(e.hint)}>({p(e.keyexpr)})"
    if isinstance(e, SemiringAgg):
        inner = ", ".join(p(x) for x in e.payload)
        return f"{e.op}({inner})"
    raise TypeError(f"unknown node {type(e)}")  # pragma: no cover


# ---------------------------------------------------------------------------
# Convenience builders (used by core.operators and tests)
# ---------------------------------------------------------------------------


def let(name: str, value: Expr, body: Expr) -> Let:
    return Let(name, value, body)


def seq(*exprs: Expr) -> Expr:
    exprs = [x for x in exprs if not isinstance(x, Noop)]
    if not exprs:
        return Noop()
    out = exprs[-1]
    for x in reversed(exprs[:-1]):
        out = Seq(x, out)
    return out


def record(**fields: Expr) -> RecordCtor:
    return RecordCtor(tuple((k, _e(v)) for k, v in fields.items()))


def const(v: Any) -> Const:
    return _e(v)  # type: ignore[return-value]
