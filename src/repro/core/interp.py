"""Reference interpreter for LLQL — the executable semantics of the language.

This is deliberately *slow and obviously correct*: dictionaries are Python
dicts, records are immutable field maps, iteration follows the annotation
(``@st``-family iterates in key order, ``@ht``-family in insertion order).
It is the oracle for (1) the vectorized JAX lowering in ``core.lower`` and
(2) the per-backend dictionary implementations in ``repro.dicts``.

Besides values, the interpreter collects **operation statistics** per
dictionary symbol (inserts, hits, misses, hinted ops, orderedness of the
access sequence).  The cost-model tests use these to validate the static
cost inference of ``core.cost`` against actually-executed operation counts —
the paper's Γ/Σ reasoning checked against ground truth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field as dfield
from typing import Any, Dict, List, Optional, Tuple

from . import llql as L

# ---------------------------------------------------------------------------
# Runtime values
# ---------------------------------------------------------------------------


class Missing:
    """Result of a failed lookup: behaves as additive zero, empty dict, and a
    record of zeros — matching the paper's bag semantics where absent keys
    have multiplicity 0."""

    _inst: Optional["Missing"] = None

    def __new__(cls) -> "Missing":
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self) -> str:
        return "MISSING"


MISSING = Missing()


@dataclass(frozen=True)
class Rec:
    """Immutable record value; supports field-wise + and scalar *."""

    fields: Tuple[Tuple[str, Any], ...]

    def get(self, name: str) -> Any:
        for a, v in self.fields:
            if a == name:
                return v
        raise KeyError(f"record has no field {name!r}: {self.fields}")

    def names(self) -> Tuple[str, ...]:
        return tuple(a for a, _ in self.fields)

    def __add__(self, other: Any) -> "Rec":
        if isinstance(other, Missing):
            return self
        assert isinstance(other, Rec) and self.names() == other.names(), (
            f"record shape mismatch: {self.names()} vs {other}"
        )
        return Rec(
            tuple(
                (a, value_add(v, other.get(a))) for a, v in self.fields
            )
        )

    __radd__ = __add__

    def __mul__(self, s: Any) -> "Rec":
        return Rec(tuple((a, v * s) for a, v in self.fields))

    __rmul__ = __mul__

    def sort_key(self) -> Tuple:
        return tuple(v for _, v in self.fields)

    def __repr__(self) -> str:
        return "{" + ", ".join(f"{a}={v}" for a, v in self.fields) + "}"


@dataclass(frozen=True)
class SRVal:
    """A semiring lane value (``L.SemiringAgg``): a scalar that combines
    under its own monoid instead of ``+``.  Multiplicity scaling applies to
    additive lanes only — ``min``/``max`` over a bag ignore multiplicity."""

    op: str  # combine monoid: "sum" | "min" | "max"
    value: Any

    def __add__(self, other: Any) -> "SRVal":
        if isinstance(other, Missing):
            return self
        if isinstance(other, SRVal):
            assert other.op == self.op, f"lane combine mismatch {self.op}/{other.op}"
            o = other.value
        else:
            # a ref cell's pristine zero record: identity for every monoid
            if other == 0:
                return self
            o = other
        if self.op == "min":
            return SRVal(self.op, min(self.value, o))
        if self.op == "max":
            return SRVal(self.op, max(self.value, o))
        return SRVal(self.op, self.value + o)

    __radd__ = __add__

    def __mul__(self, s: Any) -> "SRVal":
        if self.op == "sum":
            return SRVal(self.op, self.value * s)
        return self

    __rmul__ = __mul__

    def __repr__(self) -> str:
        return f"{self.op}:{self.value}"


def sr_value(v: Any) -> Any:
    """Unwrap a semiring lane value to its plain scalar."""
    return v.value if isinstance(v, SRVal) else v


@dataclass
class OpStats:
    """Per-dictionary operation counters — ground truth for the cost model."""

    inserts: int = 0
    update_hits: int = 0
    lookup_hits: int = 0
    lookup_misses: int = 0
    hinted_lookups: int = 0
    hinted_updates: int = 0
    # orderedness of the *update* and *lookup* key sequences
    update_keys_sorted: bool = True
    lookup_keys_sorted: bool = True
    _last_update_key: Any = None
    _last_lookup_key: Any = None

    def note_update(self, k: Any, hit: bool, hinted: bool) -> None:
        if hit:
            self.update_hits += 1
        else:
            self.inserts += 1
        if hinted:
            self.hinted_updates += 1
        kk = _orderable(k)
        if self._last_update_key is not None and kk < self._last_update_key:
            self.update_keys_sorted = False
        self._last_update_key = kk

    def note_lookup(self, k: Any, hit: bool, hinted: bool) -> None:
        if hit:
            self.lookup_hits += 1
        else:
            self.lookup_misses += 1
        if hinted:
            self.hinted_lookups += 1
        kk = _orderable(k)
        if self._last_lookup_key is not None and kk < self._last_lookup_key:
            self.lookup_keys_sorted = False
        self._last_lookup_key = kk


def _orderable(k: Any) -> Any:
    return k.sort_key() if isinstance(k, Rec) else k


class LDict:
    """An LLQL dictionary at runtime: a mutable map + its ``@ds`` annotation
    + op statistics.  ``@st``-family implementations iterate in key order."""

    def __init__(self, ds: Optional[str], name: str = "<anon>") -> None:
        self.ds = ds
        self.name = name
        self.data: Dict[Any, Any] = {}
        self.stats = OpStats()

    # -- semantics ---------------------------------------------------------
    def lookup(self, k: Any, hinted: bool = False) -> Any:
        hit = k in self.data
        self.stats.note_lookup(k, hit, hinted)
        return self.data[k] if hit else MISSING

    def update_add(self, k: Any, v: Any, hinted: bool = False) -> None:
        hit = k in self.data
        self.stats.note_update(k, hit, hinted)
        if hit:
            self.data[k] = value_add(self.data[k], v)
        else:
            self.data[k] = v

    def items(self) -> List[Tuple[Any, Any]]:
        if self.ds is not None and self.ds.startswith("st"):
            return sorted(self.data.items(), key=lambda kv: _orderable(kv[0]))
        return list(self.data.items())

    def __len__(self) -> int:
        return len(self.data)

    def __repr__(self) -> str:
        ann = f"@{self.ds} " if self.ds else ""
        return ann + "{{" + ", ".join(f"{k} -> {v}" for k, v in self.items()) + "}}"


class ItHint:
    """Iterator hint object (``d.iter``); position-carrying, per the paper."""

    def __init__(self, d: LDict) -> None:
        self.dict = d
        self.pos_key: Any = None  # last key serviced through this hint


@dataclass
class RefCell:
    value: Any

    def add(self, v: Any) -> None:
        self.value = value_add(self.value, v)


def value_add(a: Any, b: Any) -> Any:
    if isinstance(a, Missing):
        return b
    if isinstance(b, Missing):
        return a
    if isinstance(a, LDict) and isinstance(b, LDict):
        for k, v in b.items():
            a.update_add(k, v)
        return a
    return a + b


def zero_of(t: L.Type) -> Any:
    if isinstance(t, L.ScalarT):
        return {"int": 0, "double": 0.0, "bool": False, "string": ""}[t.kind]
    if isinstance(t, L.RecordT):
        return Rec(tuple((a, zero_of(ft)) for a, ft in t.fields))
    if isinstance(t, L.DictT):
        return LDict(t.ds)
    raise TypeError(f"no zero for {t}")


# ---------------------------------------------------------------------------
# The interpreter
# ---------------------------------------------------------------------------

_BINOPS = {
    "+": value_add,
    "-": lambda a, b: a - b,
    "*": lambda a, b: (a * b),
    "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "&&": lambda a, b: bool(a) and bool(b),
    "||": lambda a, b: bool(a) or bool(b),
    "min": lambda a, b: min(a, b),
    "max": lambda a, b: max(a, b),
}


class Interp:
    def __init__(
        self,
        database: Optional[Dict[str, Any]] = None,
        params: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.database = dict(database or {})
        self.params = dict(params or {})
        self.dicts: Dict[str, LDict] = {}  # let-bound dicts, for stats readout

    def run(self, e: L.Expr) -> Any:
        return self._eval(e, {})

    # -- helpers -----------------------------------------------------------
    def _as_ldict(self, v: Any, name: str = "<input>") -> LDict:
        if isinstance(v, LDict):
            return v
        if isinstance(v, dict):
            d = LDict(None, name)
            d.data = dict(v)
            return d
        raise TypeError(f"not a dictionary: {v!r}")

    # -- eval --------------------------------------------------------------
    def _eval(self, e: L.Expr, env: Dict[str, Any]) -> Any:
        if isinstance(e, L.Const):
            return e.value
        if isinstance(e, L.Param):
            if e.name not in self.params:
                raise NameError(f"unbound parameter {e.name}")
            return self.params[e.name]
        if isinstance(e, L.Var):
            if e.name not in env:
                raise NameError(f"unbound variable {e.name}")
            return env[e.name]
        if isinstance(e, L.Input):
            if e.name not in self.database:
                raise NameError(f"unknown input relation {e.name}")
            v = self.database[e.name]
            self.database[e.name] = v = self._as_ldict(v, e.name)
            return v
        if isinstance(e, L.Noop):
            return None
        if isinstance(e, L.Seq):
            self._eval(e.first, env)
            return self._eval(e.second, env)
        if isinstance(e, L.Let):
            v = self._eval(e.value, env)
            if isinstance(v, LDict) and v.name == "<anon>":
                v.name = e.name
                self.dicts[e.name] = v
            env2 = dict(env)
            env2[e.name] = v
            return self._eval(e.body, env2)
        if isinstance(e, L.If):
            c = self._eval(e.cond, env)
            return self._eval(e.then if c else e.els, env)
        if isinstance(e, L.RecordCtor):
            return Rec(tuple((a, self._eval(x, env)) for a, x in e.fields))
        if isinstance(e, L.FieldAccess):
            r = self._eval(e.rec, env)
            if isinstance(r, Missing):
                return MISSING
            if isinstance(r, RefCell):
                r = r.value
            assert isinstance(r, Rec), f"field access on non-record {r!r}"
            return sr_value(r.get(e.name))
        if isinstance(e, L.SemiringAgg):
            v = self._eval(e.contribution(), env)
            if isinstance(v, Missing):
                return MISSING
            return SRVal(e.combine, v)
        if isinstance(e, L.BinOp):
            a = self._eval(e.lhs, env)
            b = self._eval(e.rhs, env)
            if isinstance(a, Missing) or isinstance(b, Missing):
                return self._missing_binop(e.op, a, b)
            return _BINOPS[e.op](a, b)
        if isinstance(e, L.UnOp):
            v = self._eval(e.operand, env)
            if e.op == "!":
                return not v
            if e.op == "floor":
                import math

                return float(math.floor(v))
            return -v
        if isinstance(e, L.RefNew):
            return RefCell(zero_of(e.type))
        if isinstance(e, L.RefAdd):
            cell = self._eval(e.ref, env)
            assert isinstance(cell, RefCell)
            cell.add(self._eval(e.value, env))
            return None
        if isinstance(e, L.DictNew):
            d = LDict(e.ds)
            if e.key is not None:
                d.update_add(self._eval(e.key, env), self._eval(e.val, env))
                # singleton construction isn't a dictionary *operation*
                d.stats = OpStats()
            return d
        if isinstance(e, L.For):
            src = self._eval(e.source, env)
            if isinstance(src, Missing):
                return None
            src = self._as_ldict(src)
            env2 = dict(env)
            for k, v in src.items():
                env2[e.var] = Rec((("key", k), ("val", v)))
                self._eval(e.body, env2)
            return None
        if isinstance(e, L.DictUpdate):
            d = self._as_ldict(self._eval(e.dict, env))
            v = self._eval(e.value, env)
            if isinstance(v, Missing):
                return None  # missing probe ⇒ empty inner loop ⇒ no update
            d.update_add(self._eval(e.keyexpr, env), v)
            return None
        if isinstance(e, L.DictLookup):
            d = self._as_ldict(self._eval(e.dict, env))
            return d.lookup(self._eval(e.keyexpr, env))
        if isinstance(e, L.DictIter):
            return ItHint(self._as_ldict(self._eval(e.dict, env)))
        if isinstance(e, L.HintedUpdate):
            d = self._as_ldict(self._eval(e.dict, env))
            it = self._eval(e.hint, env)
            assert isinstance(it, ItHint) and it.dict is d, "hint/dict mismatch"
            k = self._eval(e.keyexpr, env)
            v = self._eval(e.value, env)
            if isinstance(v, Missing):
                return None
            d.update_add(k, v, hinted=True)
            it.pos_key = k
            return None
        if isinstance(e, L.HintedLookup):
            d = self._as_ldict(self._eval(e.dict, env))
            it = self._eval(e.hint, env)
            assert isinstance(it, ItHint) and it.dict is d, "hint/dict mismatch"
            k = self._eval(e.keyexpr, env)
            it.pos_key = k
            return d.lookup(k, hinted=True)
        raise TypeError(f"cannot interpret {type(e)}")  # pragma: no cover

    @staticmethod
    def _missing_binop(op: str, a: Any, b: Any) -> Any:
        # MISSING is additive zero and multiplicative annihilator; comparisons
        # against MISSING are vacuously false (absent row matches nothing).
        if op == "+":
            return value_add(a, b)
        if op in ("*", "-", "/"):
            if op == "-" and isinstance(b, Missing):
                return a
            return MISSING if op in ("*", "/") else (b if op == "-" else MISSING)
        if op in ("==", "!=", "<", "<=", ">", ">=", "&&"):
            return False
        if op == "||":
            return bool(a) if not isinstance(a, Missing) else bool(b) if not isinstance(b, Missing) else False
        raise TypeError(f"binop {op} on MISSING")


# ---------------------------------------------------------------------------
# Helpers to build relation inputs (bag semantics: row-record -> multiplicity)
# ---------------------------------------------------------------------------


def relation(rows: List[Dict[str, Any]], name: str = "<rel>") -> LDict:
    """Build an input relation as a dictionary row-record -> multiplicity."""
    d = LDict(None, name)
    for row in rows:
        k = Rec(tuple(sorted(row.items())))
        d.data[k] = d.data.get(k, 0) + 1
    return d


def run(
    e: L.Expr,
    database: Optional[Dict[str, Any]] = None,
    params: Optional[Dict[str, Any]] = None,
) -> Any:
    return Interp(database, params=params).run(e)
