"""LLQL cost model — the paper's Fig. 8 inference rules.

Combines three ingredients, exactly as the paper does:

* **Σ** (``cardinality.CardModel``) — cardinalities, distinct counts,
  selectivities, physical orderedness of inputs;
* **Δ** (``DictCostModel`` protocol) — per-operation dictionary costs.  The
  production Δ is *learned* from installation-stage profiling
  (``repro.costmodel``); ``AnalyticCostModel`` below is a closed-form fallback
  used by unit tests and as a sanity prior;
* **Γ** (``Gamma``) — the runtime context threaded through the rules:
  accumulated invocation count ``Γ_calls``, path probability ``Γ_cond``, and
  the dictionary-implementation assignment ``Γ_dict``.

The inference walks the program once, maintaining per-dictionary metadata
(estimated cardinality, nested-group size, build orderedness), and emits both
a total cost and a per-site breakdown (for the paper-style "explain" output
in the benchmarks).

Deviation from the paper (documented): Fig. 8's lookup rule sets the hit
fraction σ = Σ_dist(e2)/N, which exceeds 1 whenever the probe side has more
distinct keys than the dictionary.  We use the standard containment form
σ = min(1, N / Σ_dist(e2)) — identical on the paper's key/foreign-key
workloads, well-behaved elsewhere.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol, Tuple, Union

from . import llql as L
from .cardinality import CardModel, key_columns

DEFAULT_DS = "ht_linear"

# Vectorized-engine counting (DESIGN.md §2, EXPERIMENTS.md §Perf finding):
# on batch-vectorized hardware a masked (filtered) loop still runs every
# row through the dictionary op, and a masked build cannot use the
# sorted-input fast path (dicts.base re-sorts under a mask).  The paper's
# per-row rules (Fig. 8 exactly) are recovered with vectorized=False.
VECTORIZED_DEFAULT = True

# ---------------------------------------------------------------------------
# Δ — dictionary cost model interface
# ---------------------------------------------------------------------------

OPS = ("insert", "lookup_hit", "lookup_miss")


class DictCostModel(Protocol):
    def op_cost(self, ds: str, op: str, n: float, size: float, ordered: bool) -> float:
        """Total cost in **seconds** of ``n`` operations of kind ``op`` against
        a dictionary of (final) cardinality ``size``; ``ordered`` = the key
        sequence of the n operations is sorted."""
        ...


# Per-op leading coefficients (nanoseconds) of the analytic shapes below:
# hash entries are keyed (ds, op) — order-insensitive; sort entries are
# keyed (ds, op, ordered) — the ordered coefficient is the flat amortized
# per-op cost of the hinted fast path, the unordered one multiplies log2(N).
PRIOR_OP_NS = {
    ("ht_linear", "insert"): 26.0,
    ("ht_linear", "lookup_hit"): 18.0,
    ("ht_linear", "lookup_miss"): 34.0,
    ("ht_twochoice", "insert"): 38.0,
    ("ht_twochoice", "lookup_hit"): 22.0,
    ("ht_twochoice", "lookup_miss"): 24.0,
    ("st_sorted", "insert", True): 7.0,
    ("st_sorted", "lookup_hit", True): 9.0,
    ("st_sorted", "lookup_miss", True): 9.0,
    ("st_blocked", "insert", True): 6.3,
    ("st_blocked", "lookup_hit", True): 8.1,
    ("st_blocked", "lookup_miss", True): 8.1,
    ("st_sorted", "insert", False): 14.0,
    ("st_sorted", "lookup_hit", False): 11.0,
    ("st_sorted", "lookup_miss", False): 11.0,
    ("st_blocked", "insert", False): 14.0,
    ("st_blocked", "lookup_hit", False): 6.05,
    ("st_blocked", "lookup_miss", False): 6.05,
}

# Coefficients fitted against a measured sweep on the reference engine
# (``benchmarks/profile_dicts.py`` — the paper's profiled-regression story
# in miniature: same closed-form shapes, leading constants regressed by
# median ratio from ``costmodel.profiler`` timings; rank agreement 0.98
# over 345 well-separated pairs at fit time).  The sweep they were fitted
# to is committed as benchmarks/baselines/BENCH_profile_dicts.json and
# tests/test_cost_calibration.py replays it: predicted per-op rankings
# must keep matching the measured ones.  Note the vectorized-engine truths
# the priors missed: a batch hash insert costs ~µs/op at these batch
# shapes (round-based scatter arbitration), while an ordered sort-family
# build is ~100 ns/op and an unordered one ~30·log2(N) — which is exactly
# why Algorithm 1 under this Δ favours ``st_*<hinted>`` builds on sorted
# fact streams.
CALIBRATED_OP_NS = {
    ("ht_linear", "insert"): 2418.17,
    ("ht_linear", "lookup_hit"): 75.26,
    ("ht_linear", "lookup_miss"): 70.04,
    ("ht_twochoice", "insert"): 2049.99,
    ("ht_twochoice", "lookup_hit"): 86.7,
    ("ht_twochoice", "lookup_miss"): 77.56,
    ("st_blocked", "insert", False): 29.56,
    ("st_blocked", "insert", True): 109.98,
    ("st_blocked", "lookup_hit", False): 22.21,
    ("st_blocked", "lookup_hit", True): 298.79,
    ("st_blocked", "lookup_miss", False): 21.31,
    ("st_blocked", "lookup_miss", True): 266.21,
    ("st_sorted", "insert", False): 29.79,
    ("st_sorted", "insert", True): 106.07,
    ("st_sorted", "lookup_hit", False): 5.68,
    ("st_sorted", "lookup_hit", True): 56.04,
    ("st_sorted", "lookup_miss", False): 4.74,
    ("st_sorted", "lookup_miss", True): 50.07,
}


class AnalyticCostModel:
    """Closed-form Δ with plausible big-O shapes and table-driven constants.

    Used by unit tests and as the pre-installation prior; the learned model
    (``repro.costmodel.store.load_model``) replaces it after profiling.
    ``constants`` selects the leading coefficients: ``"prior"`` (hand-set
    plausible values — the default, stable for unit tests) or
    ``"calibrated"`` (fitted from the measured sweep), or an explicit
    table.  Only *relative* shape matters for synthesis.

    ``corrections`` is the ONLINE recalibration table (DESIGN.md §11): a
    per-(ds, op[, ordered]) multiplicative factor, updated from
    measured-vs-predicted residuals by the adaptive planner
    (``core.adapt``) as raced candidates report real wall times.  It
    starts empty (identity) and deforms the installed constants toward
    what this process actually measures — the serving-time continuation
    of the offline profiled regression.
    """

    def __init__(
        self, scale: float = 1.0, constants="prior", corrections=None
    ) -> None:
        self.scale = scale
        if constants == "prior":
            self.table = PRIOR_OP_NS
        elif constants == "calibrated":
            self.table = CALIBRATED_OP_NS
        else:
            self.table = dict(constants)
        self.corrections: Dict[tuple, float] = dict(corrections or {})

    @classmethod
    def calibrated(cls, scale: float = 1.0) -> "AnalyticCostModel":
        return cls(scale, constants="calibrated")

    @staticmethod
    def op_key(ds: str, op: str, ordered: bool) -> tuple:
        if ds.startswith("ht"):
            return (ds, op)
        if ds.startswith("st"):
            return (ds, op, bool(ordered))
        raise KeyError(f"unknown dictionary implementation {ds!r}")

    def correction(self, ds: str, op: str, ordered: bool = False) -> float:
        return self.corrections.get(self.op_key(ds, op, ordered), 1.0)

    def apply_residual(
        self,
        ds: str,
        op: str,
        ordered: bool,
        ratio: float,
        alpha: float = 0.5,
    ) -> float:
        """One online-recalibration step: nudge the (ds, op) correction a
        geometric ``alpha`` of the way toward the observed
        measured/predicted ratio (predicted under the CURRENT corrections,
        so repeated consistent observations converge the factor).  Returns
        the updated correction."""
        key = self.op_key(ds, op, ordered)
        ratio = min(max(float(ratio), 1e-3), 1e3)
        cur = self.corrections.get(key, 1.0)
        new = min(max(cur * ratio ** float(alpha), 1e-4), 1e4)
        self.corrections[key] = new
        return new

    @staticmethod
    def shape_factor(ds: str, op: str, size: float, ordered: bool) -> float:
        """The size-dependent multiplier of the per-op cost — everything in
        ``op_cost`` except the leading coefficient.  Shared with the fitter
        (``benchmarks/profile_dicts.py``) so fitted constants live in
        exactly the model's shape family."""
        size = max(2.0, float(size))
        lg = math.log2(size)
        if ds.startswith("ht"):
            return 1.0 + 0.12 * max(0.0, lg - 10.0)  # past-L1 growth
        if ordered:
            # hinted/merge access or append-build: amortized O(1)
            return 1.0
        growth = 1.0 + 0.05 * max(0.0, lg - 13.0)
        # unordered sorted-dict build ~ sort, lookup ~ binary search:
        # O(log n) amortized per op
        return lg * growth

    def op_cost(self, ds: str, op: str, n: float, size: float, ordered: bool) -> float:
        n = max(0.0, float(n))
        if n == 0.0:
            return 0.0
        key = self.op_key(ds, op, ordered)
        per = (
            self.table[key]
            * self.corrections.get(key, 1.0)
            * self.shape_factor(ds, op, size, ordered)
        )
        return self.scale * n * per * 1e-9


# ---------------------------------------------------------------------------
# Γ — runtime context & synthesis choices
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DictChoice:
    ds: str = DEFAULT_DS
    hinted: bool = False  # use hinted (iterator/merge) probe & insert sites
    # Distributed placement of a dictionary built from sharded rows:
    # "partition" — hash-repartition the build rows by key, per-shard slices,
    #               probes repartitioned to match (co-partitioned join);
    # "broadcast" — all-gather the build rows, replicated copy, local probes;
    # ""          — unplaced (single-shard plans; legalizer defaults to
    #               "partition").  Chosen by Alg. 1 under Δ_net.
    placement: str = ""

    def __str__(self) -> str:
        s = self.ds + ("<hinted>" if self.hinted else "")
        return s + (f"@{self.placement}" if self.placement else "")


GammaDict = Dict[str, DictChoice]


# ---------------------------------------------------------------------------
# Δ_net — exchange/shuffle cost for the distributed plan realization
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetCostModel:
    """α-β model of the cross-shard Exchange the sharded executor inserts
    after every dictionary built from sharded inputs (DESIGN.md §4): each
    shard's partial dictionary is routed by key hash through an all-to-all,
    then merged by one local build.  ``shuffle_seconds`` prices the wire
    traffic; the merge build is priced through Δ by the caller."""

    n_shards: int = 1
    alpha: float = 2e-6  # per-collective latency (s) — one all-to-all phase
    beta: float = 1.0 / 10e9  # seconds per byte through the interconnect
    key_bytes: float = 4.0  # int32 keys
    lane_bytes: float = 4.0  # f32 value lanes

    def entry_bytes(self, lanes: float = 1.0) -> float:
        return self.key_bytes + self.lane_bytes * max(1.0, lanes)

    def shuffle_seconds(self, entries: float, lanes: float = 1.0) -> float:
        if self.n_shards <= 1 or entries <= 0:
            return 0.0
        hops = math.log2(max(2.0, float(self.n_shards)))
        return self.alpha * hops + entries * self.entry_bytes(lanes) * self.beta

    def repartition_seconds(self, rows: float, lanes: float = 1.0) -> float:
        """Hash all-to-all of ``rows`` global rows: per-shard wall clock —
        each shard sends and receives ~rows/n_shards entries."""
        if self.n_shards <= 1 or rows <= 0:
            return 0.0
        hops = math.log2(max(2.0, float(self.n_shards)))
        per_shard = rows / float(self.n_shards)
        return self.alpha * hops + per_shard * self.entry_bytes(lanes) * self.beta

    def broadcast_seconds(self, rows: float, lanes: float = 1.0) -> float:
        """All-gather of ``rows`` global rows onto every shard: each shard
        receives the (n-1)/n of the rows it does not already hold."""
        if self.n_shards <= 1 or rows <= 0:
            return 0.0
        hops = math.log2(max(2.0, float(self.n_shards)))
        recv = rows * (1.0 - 1.0 / float(self.n_shards))
        return self.alpha * hops + recv * self.entry_bytes(lanes) * self.beta


# ---------------------------------------------------------------------------
# Δ_fuse — the fuse-vs-materialize term for pipeline regions (DESIGN.md §7)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FusionCostModel:
    """Prices the per-region fuse/materialize decision of ``plan.fuse``.

    Fusing a ``Scan → Select* → HashProbe* → GroupBy/Reduce`` chain into one
    streaming kernel saves the HBM round-trips of every elided intermediate
    (masks written+reread by the next operator, probe-gathered build-side
    columns materialized at probe-stream width) at the price of keeping the
    probed dictionaries *and* their gather payloads co-resident in VMEM for
    the whole pass.  Δ_fuse is therefore

        saved_bytes / hbm_bytes_per_sec      if resident ≤ vmem_budget
        -inf                                 otherwise (must split)

    — a fused region is profitable whenever it elides any intermediate and
    its working set fits; a region that does not fit is split at probe
    boundaries (the overflowing probe materializes, the rest stays fused).
    Constants are deliberately coarse: only the *sign* and the budget
    comparison drive planning, mirroring how Δ_net only needs relative
    ordering.
    """

    hbm_bytes_per_sec: float = 8.0e11  # ~TPU HBM stream bandwidth
    vmem_budget: int = 8 << 20  # bytes for co-resident dicts + payloads
    mask_bytes: float = 2.0  # bool intermediate: write + reread
    col_bytes: float = 8.0  # f32/int32 intermediate: write + reread
    key_bytes: float = 4.0
    lane_bytes: float = 4.0
    default_rows: float = float(1 << 16)  # unknown-source fallback
    default_cols: float = 4.0  # unknown build-side width fallback
    # -- radix-partitioned fused execution (DESIGN.md §8) -------------------
    kernel_slots: int = 1 << 16  # per-dictionary resident slot bound (the
    # fused kernel's VMEM contract; a dictionary over it must partition)
    max_partitions: int = 64  # 0 or 1 disables the partitioned mode
    partition_pass_factor: float = 1.0  # the routing pass costs ~this many
    # stream round-trips (col_bytes already counts write + reread)
    probe_random_bytes: float = 32.0  # effective HBM bytes per probe of a
    # NON-resident dictionary — random gathers are latency-bound, not
    # bandwidth-bound, so an out-of-VMEM probe costs far more than its 4-byte
    # payload; this is the TPU translation of the paper's cache-consciousness
    # argument, and the term that makes co-residing a partitioned slab worth
    # one extra routing pass over the fact stream
    # -- chained out-of-core streaming (DESIGN.md §10/§11) ------------------
    chunk_rows: float = float(1 << 16)  # mirrors storage.CHUNK_ROWS — the
    # planner's estimate of how many source rows one streamed chunk holds
    spill_budget: int = 8 << 20  # device bytes a spilled-and-decoded chained
    # intermediate may occupy: beyond it the spill-and-run-resident
    # alternative is not available and the downstream chain MUST stay fused
    # onto the pending stream

    def dict_bytes(self, capacity: float, lanes: float) -> float:
        """VMEM footprint of a resident dictionary slab."""
        return float(capacity) * (
            self.key_bytes + self.lane_bytes * max(1.0, float(lanes))
        )

    def payload_bytes(self, capacity: float, ncols: float) -> float:
        """VMEM footprint of the gather payload a fused probe keeps resident
        (build-side columns re-keyed to dictionary slots — see
        ``kernels.fused_pipeline``)."""
        return float(capacity) * self.lane_bytes * max(0.0, float(ncols))

    def delta_fuse(self, saved_bytes: float, resident_bytes: float) -> float:
        """Seconds saved by fusing the region; ``-inf`` when the region's
        resident working set cannot fit the VMEM budget."""
        if resident_bytes > self.vmem_budget:
            return float("-inf")
        return float(saved_bytes) / self.hbm_bytes_per_sec

    def delta_partition(
        self,
        saved_bytes: float,
        resident_bytes: float,
        rows: float,
        stream_cols: float,
    ) -> float:
        """Seconds saved by running the region fused-*partitioned* instead
        of materialized: the full fusion saving minus the radix routing
        pass — every streamed column (plus the live mask) is written and
        reread ``partition_pass_factor`` times while rows are routed into
        tile-aligned partition runs.  ``resident_bytes`` is the
        per-grid-step working set (one partition of the oversized slab +
        every small slab + the accumulator); over-budget is ``-inf``.  The
        planner compares this against the best split-materialized
        alternative and dispatches whichever wins (``plan._decide_region``,
        rendered by ``plan.describe``)."""
        if resident_bytes > self.vmem_budget:
            return float("-inf")
        route = (
            float(rows)
            * (self.col_bytes * float(stream_cols) + self.mask_bytes)
            * self.partition_pass_factor
        )
        return (float(saved_bytes) - route) / self.hbm_bytes_per_sec

    def delta_chained(
        self,
        inter_rows: float,
        inter_cols: float,
        state_bytes: float,
        n_chunks: float,
    ) -> float:
        """Seconds saved by CHAINING a downstream region onto a pending
        Project-terminal streamed intermediate instead of spilling the
        projection and running the consumer resident.

        Chaining re-folds a carried accumulator per source chunk, and
        because the chained intermediate has no Σ row the state is sized
        for the FULL source row count; XLA's functional update rewrites
        that whole buffer every chunk, so the chained terminal pays
        ``n_chunks × state_bytes`` of state traffic where the resident
        consumer of a spilled intermediate pays it once.  Spilling pays
        the intermediate's host round-trip (write + re-read) instead.
        Below small scales the oversized per-chunk state rewrite dominates
        (~10x measured) and this goes negative → spill; a decoded
        intermediate larger than ``spill_budget`` has no resident
        alternative, so chaining is forced (``+inf``)."""
        decoded = float(inter_rows) * 4.0 * max(1.0, float(inter_cols))
        if decoded > self.spill_budget:
            return float("inf")
        spill = (
            float(inter_rows)
            * (self.col_bytes * float(inter_cols) + self.mask_bytes)
            + float(state_bytes)
        )
        merge = max(1.0, float(n_chunks)) * float(state_bytes)
        return (spill - merge) / self.hbm_bytes_per_sec

    def delta_share(self, saved_bytes: float, resident_bytes: float) -> float:
        """Seconds saved by merging fused regions from *different* plans
        into one shared-scan pass (``plan.merge_shared_scans``):
        ``saved_bytes`` is the fact-stream traffic the batch no longer
        re-reads (each merged region streams the scan once instead of once
        per query), ``resident_bytes`` the merged region's co-resident
        working set — every branch's dictionaries, gather payloads, and
        accumulator slabs now live in VMEM at the same time.  Same budget
        rule as Δ_fuse: an over-budget merge is ``-inf`` and the planner
        drops branches until the rest fit (or declines the merge)."""
        if resident_bytes > self.vmem_budget:
            return float("-inf")
        return float(saved_bytes) / self.hbm_bytes_per_sec


# ---------------------------------------------------------------------------
# out-of-core storage: per-encoding decode + H2D transfer terms (DESIGN §10)
# ---------------------------------------------------------------------------

#: chunk encodings the storage layer can choose per column (data/storage.py)
ENCODINGS = ("plain", "dict", "rle", "bitpack", "for")


@dataclass(frozen=True)
class StorageCostModel:
    """Prices the encoded-streamed vs decoded-resident decision per column.

    A *streamed* column pays host→device transfer for its **encoded** bytes
    on every pass plus an in-register decode; a *resident* column pays the
    transfer of its **decoded** bytes once and device-memory rent forever.
    Alg. 1's storage extension scores each encoding as

        h2d_seconds(encoded_bytes) + decode_seconds(kind, rows)

    and picks the cheapest representation whose working set fits the
    explicit ``memory_budget_bytes`` (``storage_plan``).  Decode rates are
    elements/second of the vectorized shift-mask (bit-packed / FOR),
    gather (dictionary), and run-expansion (RLE) loops — decode is far
    cheaper than the transfer it elides, which is why compression wins.
    """

    h2d_bytes_per_sec: float = 2.5e10  # PCIe-ish host→device bandwidth
    device_bytes_per_sec: float = 8.0e11  # post-decode on-device traffic
    decode_plain: float = float("inf")  # elems/sec (no decode work)
    decode_bitpack: float = 2.0e10  # shift + mask unpack
    decode_for: float = 1.8e10  # unpack + reference add
    decode_dict: float = 1.2e10  # unpack + values gather
    decode_rle: float = 6.0e9  # run-boundary compare + gather
    chunk_fixed_seconds: float = 2.0e-5  # per-chunk dispatch overhead

    def h2d_seconds(self, nbytes: float) -> float:
        return float(nbytes) / self.h2d_bytes_per_sec

    def decode_seconds(self, kind: str, rows: float) -> float:
        rate = getattr(self, "decode_" + ("for" if kind == "for" else kind))
        if rate == float("inf"):
            return 0.0
        return float(rows) / rate

    def encoding_seconds(self, kind: str, encoded_bytes: float, rows: float) -> float:
        """Per-pass cost of streaming a column under ``kind``: move the
        encoded bytes over the host→device link, then decode in-register."""
        return self.h2d_seconds(encoded_bytes) + self.decode_seconds(kind, rows)

    def stream_seconds(
        self, encoded_bytes: float, rows: float, kinds: Dict[str, str],
        col_bytes: Dict[str, float], n_chunks: int = 1,
    ) -> float:
        """Whole-relation per-pass streaming cost: Σ per-column encoding
        cost + per-chunk dispatch overhead."""
        total = self.chunk_fixed_seconds * max(1, int(n_chunks))
        for col, kind in kinds.items():
            total += self.encoding_seconds(kind, col_bytes.get(col, 0.0), rows)
        return total


def encoded_bytes_estimate(
    kind: str,
    rows: float,
    distinct: float,
    lo: float,
    hi: float,
    runs: float,
    is_float: bool,
    block: int = 1024,
) -> float:
    """Estimated encoded size in bytes of one column chunk under ``kind``,
    from Σ statistics alone (the exact sizes come from data/storage.py once
    a representation is materialized; this is what Alg. 1 prices *before*
    choosing).  ``inf`` marks an inapplicable encoding (bit-packing floats,
    ranges wider than 16 bits, ...) — block-aligned padding is included so
    the estimate matches the tile form the kernel actually streams."""
    rows = max(1.0, float(rows))
    n_tiles = -(-rows // block)

    def _width(span: float) -> Optional[int]:
        bits = max(1, int(max(0.0, span)).bit_length())
        for w in (1, 2, 4, 8, 16):
            if bits <= w:
                return w
        return None

    if kind == "plain":
        return 4.0 * rows
    if kind == "bitpack":
        if is_float or lo < 0:
            return float("inf")
        w = _width(hi)
        return float("inf") if w is None else n_tiles * block * w / 8.0
    if kind == "for":
        if is_float:
            return float("inf")
        w = _width(hi - lo)
        return float("inf") if w is None else n_tiles * block * w / 8.0 + 4.0
    if kind == "dict":
        w = _width(max(0.0, distinct - 1))
        if w is None:
            return float("inf")
        return 4.0 * distinct + n_tiles * block * w / 8.0
    if kind == "rle":
        # tile form pads every tile to the worst tile's run count; estimate
        # uniform spread plus one boundary-split run per tile
        per_tile = runs / n_tiles + 1.0
        return n_tiles * per_tile * 8.0
    raise ValueError(f"unknown encoding {kind!r}")


def choose_encoding(
    rows: float,
    distinct: float,
    lo: float,
    hi: float,
    runs: float,
    is_float: bool,
    model: Optional[StorageCostModel] = None,
    block: int = 1024,
) -> str:
    """Pick the cheapest encoding for one column chunk under the storage
    cost model: minimize H2D transfer + in-register decode per pass.  Plain
    wins ties — decode work is only worth paying when it elides bytes."""
    model = model or StorageCostModel()
    best, best_s = "plain", model.encoding_seconds(
        "plain", encoded_bytes_estimate("plain", rows, distinct, lo, hi, runs, is_float, block), rows
    )
    for kind in ("rle", "bitpack", "for", "dict"):
        b = encoded_bytes_estimate(kind, rows, distinct, lo, hi, runs, is_float, block)
        if b >= 4.0 * rows:  # never pay decode for zero compression
            continue
        s = model.encoding_seconds(kind, b, rows)
        if s < best_s:
            best, best_s = kind, s
    return best


@dataclass
class StorageDecision:
    """One relation's placement under ``storage_plan``."""

    rel: str
    mode: str  # "resident" | "streamed"
    decoded_bytes: float
    encoded_bytes: float
    per_pass_seconds: float
    encodings: Dict[str, str] = field(default_factory=dict)


def storage_plan(
    sigma,
    memory_budget_bytes: int,
    model: Optional[StorageCostModel] = None,
    block: int = 1024,
    chunk_rows: int = 1 << 16,
) -> Dict[str, StorageDecision]:
    """Alg. 1's storage extension: given Σ and an explicit device
    ``memory_budget_bytes``, decide per relation whether its columns live
    decoded-resident (pay decoded H2D once, rent device memory) or
    encoded-streamed (pay encoded H2D + decode per pass, rent only the
    double-buffered chunk working set).  Relations are kept resident
    cheapest-first while they fit the budget; the rest stream with
    per-column encodings chosen by ``choose_encoding``.
    """
    model = model or StorageCostModel()
    rels = []
    for rel, st in sorted(sigma.rels.items()):
        decoded = 4.0 * st.rows * max(1, len(st.columns))
        encodings, encoded = {}, 0.0
        for c, cs in sorted(st.columns.items()):
            is_float = float(cs.lo) != float(int(cs.lo)) or float(cs.hi) != float(int(cs.hi))
            runs = st.rows if st.sorted_on[:1] != (c,) else max(1.0, cs.distinct)
            kind = choose_encoding(
                st.rows, cs.distinct, cs.lo, cs.hi, runs, is_float, model, block
            )
            encodings[c] = kind
            encoded += encoded_bytes_estimate(
                kind, st.rows, cs.distinct, cs.lo, cs.hi, runs, is_float, block
            )
        rels.append((decoded, rel, st, encodings, encoded))

    out: Dict[str, StorageDecision] = {}
    spent = 0.0
    for decoded, rel, st, encodings, encoded in sorted(rels):
        n_chunks = max(1, -(-int(st.rows) // chunk_rows))
        stream_s = model.stream_seconds(
            encoded, st.rows,
            encodings, {c: encoded / max(1, len(encodings)) for c in encodings},
            n_chunks,
        )
        if spent + decoded <= memory_budget_bytes:
            spent += decoded
            out[rel] = StorageDecision(rel, "resident", decoded, encoded, 0.0, encodings)
        else:
            out[rel] = StorageDecision(
                rel, "streamed", decoded, encoded, stream_s, encodings
            )
    return out


@dataclass
class DictMeta:
    name: str
    choice: DictChoice
    card: float = 0.0  # estimated final cardinality
    elems: float = 0.0  # total inserted elements incl. duplicates (for groups)
    nested: bool = False  # values are inner dictionaries (partition/trie dict)
    build_ordered: bool = True  # every build site saw sorted keys
    lanes: float = 1.0  # value arity (bytes on the wire for exchanges)
    build_rels: set = field(default_factory=set)  # base relations feeding builds

    @property
    def group_sz(self) -> float:
        if not self.nested or self.card <= 0:
            return 1.0
        return max(1.0, self.elems / self.card)


@dataclass
class CostItem:
    site: str  # human-readable site tag
    dict: str
    ds: str
    op: str
    n: float
    size: float
    ordered: bool
    seconds: float


@dataclass
class CostResult:
    total: float = 0.0
    items: List[CostItem] = field(default_factory=list)
    scalar_seconds: float = 0.0
    dict_meta: Dict[str, DictMeta] = field(default_factory=dict)

    def add(self, item: CostItem) -> None:
        self.items.append(item)
        self.total += item.seconds

    def add_scalar(self, seconds: float) -> None:
        self.scalar_seconds += seconds
        self.total += seconds

    def by_dict(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for it in self.items:
            out[it.dict] = out.get(it.dict, 0.0) + it.seconds
        return out

    def explain(self) -> str:
        lines = [f"total {self.total*1e3:.3f} ms (scalar {self.scalar_seconds*1e3:.3f} ms)"]
        for it in self.items:
            lines.append(
                f"  {it.site:<28} {it.dict:<8} {it.ds:<14} {it.op:<12}"
                f" n={it.n:<12.0f} size={it.size:<12.0f}"
                f" ordered={int(it.ordered)} -> {it.seconds*1e3:.3f} ms"
            )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Environment entries for the static walk
# ---------------------------------------------------------------------------


@dataclass
class RowOf:
    rel: str  # loop variable ranges over rows of this input relation


@dataclass
class InnerRowOf:
    meta: Optional[DictMeta]  # rows of an inner (group) dictionary; None=input trie
    rel: Optional[str] = None  # for input tries: the trie's stats name


@dataclass
class DictRowOf:
    meta: DictMeta  # iterating a result dictionary's key/value pairs


@dataclass
class IterOf:
    meta: DictMeta


@dataclass
class RefVal:
    pass


@dataclass
class ScalarVal:
    pass


EnvEntry = Union[RowOf, InnerRowOf, DictRowOf, IterOf, RefVal, ScalarVal, DictMeta]

SCALAR_NS = 1.4  # per scalar op (arith/field/record), calibrated vs interp
ITER_NS = 2.0  # per-element loop overhead


# ---------------------------------------------------------------------------
# The inference engine
# ---------------------------------------------------------------------------


class _Infer:
    def __init__(
        self,
        sigma: CardModel,
        delta: DictCostModel,
        gamma_dict: GammaDict,
        vectorized: bool = VECTORIZED_DEFAULT,
        ordered_off: bool = False,
    ):
        self.sigma = sigma
        self.delta = delta
        self.gamma_dict = dict(gamma_dict)
        self.vectorized = vectorized
        # the sharded executor runs with allow_sorted=False (per-shard
        # slices lose the global sort the hinted kernels assume), so the
        # distributed pricing must not credit ordered fast paths — else
        # Alg. 1 picks hinted sort builds the executor then re-sorts
        self.ordered_off = ordered_off
        self.res = CostResult()
        # probe provenance per lookup site: (dict, rows, kind, payload,
        # whole_key) — kind "rel" carries the base relation the probe stream
        # iterates, kind "dict" the DictMeta of a scanned result dictionary.
        # The distributed pricing uses this to charge probe repartitioning
        # only where plan.legalize would actually move rows.
        self.probe_log: List[Tuple[str, float, str, Any, bool]] = []

    # -- scalar expression op counting ------------------------------------
    def _scalar_ops(self, e: L.Expr) -> float:
        n = 0.0
        for node in L.walk(e):
            if isinstance(node, (L.BinOp, L.UnOp, L.FieldAccess)):
                n += 1.0
            elif isinstance(node, L.RecordCtor):
                n += len(node.fields)
        return n

    def _charge_scalar(self, e: L.Expr, calls: float) -> None:
        self.res.add_scalar(self._scalar_ops(e) * calls * SCALAR_NS * 1e-9)

    # -- source cardinality for For loops ----------------------------------
    def _loop_info(
        self, src: L.Expr, env: Dict[str, EnvEntry], calls: float
    ) -> Tuple[float, EnvEntry, Optional[str]]:
        """Returns (iterations per invocation, env entry for loop var, rel)."""
        if isinstance(src, L.Input):
            st = self.sigma.rel(src.name)
            return st.rows, RowOf(src.name), src.name
        if isinstance(src, L.Var):
            ent = env.get(src.name)
            if isinstance(ent, DictMeta):
                return ent.card, DictRowOf(ent), None
        if isinstance(src, (L.DictLookup, L.HintedLookup)):
            # probe cost charged by the lookup rule; iterate inner group
            meta = self._dict_of(src.dict, env)
            self._lookup_cost(src, env, calls, site="probe-loop")
            if meta is not None:
                return meta.group_sz, InnerRowOf(meta), None
            # lookup into an *input* dictionary (index-nested-loop join)
            rel = src.dict.name if isinstance(src.dict, L.Input) else "?"
            st = self.sigma.rel(rel)
            grp = st.rows / max(1.0, self.sigma.dist(rel, ("*",)))
            return max(1.0, grp), InnerRowOf(None, rel), None
        if isinstance(src, L.FieldAccess) and src.name == "val":
            base = src.rec
            if isinstance(base, L.Var):
                ent = env.get(base.name)
                if isinstance(ent, RowOf):
                    st = self.sigma.rel(ent.rel)
                    return max(1.0, getattr(st, "inner_rows", 1.0)), InnerRowOf(
                        None, ent.rel
                    ), ent.rel
                if isinstance(ent, DictRowOf):
                    return ent.meta.group_sz, InnerRowOf(ent.meta), None
        raise NotImplementedError(f"cannot infer loop source {src}")

    def _dict_of(self, e: L.Expr, env: Dict[str, EnvEntry]) -> Optional[DictMeta]:
        if isinstance(e, L.Var):
            ent = env.get(e.name)
            if isinstance(ent, DictMeta):
                return ent
        return None

    # -- probe-side distinct & orderedness ---------------------------------
    def _probe_stats(
        self, keyexpr: L.Expr, env: Dict[str, EnvEntry]
    ) -> Tuple[float, bool]:
        """(distinct probe keys, probe sequence sorted?) for a key expression
        evaluated inside the current innermost relation loop."""
        for node in L.walk(keyexpr):
            if isinstance(node, L.Var) and isinstance(env.get(node.name), RowOf):
                rel = env[node.name].rel  # type: ignore[union-attr]
                cols = key_columns(keyexpr, node.name)
                dist = self.sigma.dist(rel, cols)
                ordered = self.sigma.is_sorted_on(rel, cols)
                return dist, ordered
            if isinstance(node, L.Var) and isinstance(env.get(node.name), DictRowOf):
                meta = env[node.name].meta  # type: ignore[union-attr]
                # iterating a dictionary yields sorted keys for @st families
                return meta.card, meta.choice.ds.startswith("st")
        return 1.0, False

    # -- Fig. 8 lookup rule -------------------------------------------------
    def _lookup_cost(
        self,
        e: Union[L.DictLookup, L.HintedLookup],
        env: Dict[str, EnvEntry],
        calls: float,
        site: str,
        cond: float = 1.0,
    ) -> None:
        meta = self._dict_of(e.dict, env)
        self._charge_scalar(e.keyexpr, calls)
        if meta is None:
            return  # input index: charged as memory traffic by the lowering
        # vectorized engines run every physical row through the op; masked
        # rows count as misses.  Paper mode uses the semantic count.
        C = calls if self.vectorized else calls * cond
        N = max(1.0, meta.card)
        for node in L.walk(e.keyexpr):
            if isinstance(node, L.Var):
                ent = env.get(node.name)
                if isinstance(ent, RowOf):
                    self.probe_log.append((meta.name, C, "rel", ent.rel, False))
                    break
                if isinstance(ent, DictRowOf):
                    whole = key_columns(e.keyexpr, node.name) == ("*",)
                    self.probe_log.append(
                        (meta.name, C, "dict", ent.meta, whole)
                    )
                    break
        dist, probe_sorted = self._probe_stats(e.keyexpr, env)
        sigma_hit = min(1.0, N / max(1.0, dist)) * (cond if self.vectorized else 1.0)
        H = sigma_hit * C
        M = C - H
        hinted = isinstance(e, L.HintedLookup) or meta.choice.hinted
        ordered = probe_sorted and (hinted or meta.choice.ds.startswith("ht"))
        ordered = ordered and not self.ordered_off
        ds = meta.choice.ds
        for op, n in (("lookup_hit", H), ("lookup_miss", M)):
            if n <= 0:
                continue
            sec = self.delta.op_cost(ds, op, n, N, ordered)
            self.res.add(CostItem(site, meta.name, ds, op, n, N, ordered, sec))

    # -- Fig. 8 update rule --------------------------------------------------
    def _update_cost(
        self,
        e: Union[L.DictUpdate, L.HintedUpdate],
        env: Dict[str, EnvEntry],
        calls: float,
        site: str,
        cond: float = 1.0,
    ) -> None:
        meta = self._dict_of(e.dict, env)
        self._charge_scalar(e.keyexpr, calls)
        self._charge_scalar(e.value, calls)
        if meta is None:
            raise NotImplementedError("update of non-let-bound dictionary")
        C = calls if self.vectorized else calls * cond
        C_sem = calls * cond  # semantic rows that actually insert/aggregate
        dist, probe_sorted = self._probe_stats(e.keyexpr, env)
        new = max(0.0, min(dist, C_sem) - meta.card)  # containment
        H = C - new
        N = meta.card + new
        hinted = isinstance(e, L.HintedUpdate) or meta.choice.hinted
        ordered = probe_sorted and (hinted or meta.choice.ds.startswith("ht"))
        ordered = ordered and not self.ordered_off
        # NOTE: a masked vectorized build KEEPS the sorted-input fast path —
        # masked rows become PAD holes and dicts.base.dedupe_sorted merges
        # across them — so ``ordered`` is not downgraded under a mask.
        ds = meta.choice.ds
        if self.vectorized:
            # a vectorized build is ONE batched insert of every physical row
            # (hash: probe rounds over the batch; sort: argsort + segment
            # dedupe) — the paper's find-then-emplace decomposition describes
            # per-row CPU execution, not batch execution.  The profiler
            # measures exactly this op shape (n rows collapsing into N keys).
            sec = self.delta.op_cost(ds, "insert", C, max(1.0, N), ordered)
            self.res.add(
                CostItem(site, meta.name, ds, "insert", C, max(1.0, N), ordered, sec)
            )
        else:
            for op, n in (("lookup_hit", H), ("lookup_miss", new), ("insert", new)):
                if n <= 0:
                    continue
                sec = self.delta.op_cost(ds, op, n, max(1.0, N), ordered)
                self.res.add(
                    CostItem(site, meta.name, ds, op, n, max(1.0, N), ordered, sec)
                )
        meta.card = N
        meta.elems += C
        # provenance: every enclosing loop's base relations feed this build —
        # including *transitively* through derived dictionaries (a dict built
        # while iterating another dict inherits its build relations), so the
        # distributed pricing sees that e.g. Q5's OD descends from orders
        for ent in env.values():
            if isinstance(ent, RowOf):
                meta.build_rels.add(ent.rel)
            elif isinstance(ent, DictRowOf):
                meta.build_rels |= ent.meta.build_rels
            elif isinstance(ent, InnerRowOf):
                if ent.meta is not None:
                    meta.build_rels |= ent.meta.build_rels
                elif ent.rel:
                    meta.build_rels.add(ent.rel)
        for node in L.walk(e.value):
            if isinstance(node, L.RecordCtor):
                meta.lanes = max(meta.lanes, float(len(node.fields)))
                break
        if isinstance(e.value, L.DictNew) and e.value.key is not None:
            meta.nested = True
        if not ordered and not meta.choice.ds.startswith("ht"):
            meta.build_ordered = False
        if not probe_sorted:
            meta.build_ordered = False

    # -- main walk -----------------------------------------------------------
    def infer(self, e: L.Expr, env: Dict[str, EnvEntry], calls: float, site: str, cond: float = 1.0) -> None:
        if isinstance(e, (L.Const, L.Param, L.Var, L.Input, L.Noop)):
            return
        if isinstance(e, L.Seq):
            self.infer(e.first, env, calls, site)
            self.infer(e.second, env, calls, site)
            return
        if isinstance(e, L.Let):
            v = e.value
            env2 = dict(env)
            if isinstance(v, L.DictNew):
                choice = self.gamma_dict.get(e.name) or (
                    DictChoice(v.ds) if v.ds else DictChoice()
                )
                meta = DictMeta(e.name, choice)
                self.res.dict_meta[e.name] = meta
                env2[e.name] = meta
            elif isinstance(v, L.RefNew):
                env2[e.name] = RefVal()
            elif isinstance(v, L.DictIter):
                m = self._dict_of(v.dict, env)
                env2[e.name] = IterOf(m) if m else ScalarVal()
            elif isinstance(v, (L.DictLookup, L.HintedLookup)):
                self._lookup_cost(v, env, calls, site=f"let {e.name}")
                env2[e.name] = ScalarVal()
            else:
                self.infer(v, env, calls, site)
                env2[e.name] = ScalarVal()
            self.infer(e.body, env2, calls, site)
            return
        if isinstance(e, L.If):
            # find the relation the condition ranges over for Σ_sel
            sel = 0.5
            for node in L.walk(e.cond):
                if isinstance(node, L.Var) and isinstance(env.get(node.name), RowOf):
                    sel = self.sigma.sel(e.cond, node.name, env[node.name].rel)  # type: ignore[union-attr]
                    break
            # contains-style guard: If(lookup != none) -> hit-rate selectivity
            lk = _find_lookup(e.cond)
            if lk is not None:
                meta = self._dict_of(lk.dict, env)
                if meta is not None:
                    self._lookup_cost(lk, env, calls, site=f"{site}/guard", cond=cond)
                    dist, _ = self._probe_stats(lk.keyexpr, env)
                    sel = min(1.0, max(1.0, meta.card) / max(1.0, dist))
            else:
                self._charge_scalar(e.cond, calls)
            if self.vectorized:
                # masked rows still flow through the ops; selectivity rides
                # in ``cond`` (affects hit rates and dictionary sizes only)
                self.infer(e.then, env, calls, site, cond=cond * sel)
                self.infer(e.els, env, calls, site, cond=cond * (1.0 - sel))
            else:
                self.infer(e.then, env, calls * sel, site, cond=cond)
                self.infer(e.els, env, calls * (1.0 - sel), site, cond=cond)
            return
        if isinstance(e, L.For):
            n, entry, _rel = self._loop_info(e.source, env, calls)
            env2 = dict(env)
            env2[e.var] = entry
            self.res.add_scalar(calls * n * ITER_NS * 1e-9)
            self.infer(e.body, env2, calls * n, site=f"{site}/for:{e.var}", cond=cond)
            return
        if isinstance(e, (L.DictUpdate, L.HintedUpdate)):
            if isinstance(e.value, (L.DictLookup, L.HintedLookup)):
                self._lookup_cost(e.value, env, calls, site=f"{site}/val", cond=cond)
            else:
                for sub in L.walk(e.value):
                    if isinstance(sub, (L.DictLookup, L.HintedLookup)):
                        self._lookup_cost(sub, env, calls, site=f"{site}/val", cond=cond)
            self._update_cost(e, env, calls, site=f"{site}/update", cond=cond)
            return
        if isinstance(e, (L.DictLookup, L.HintedLookup)):
            self._lookup_cost(e, env, calls, site=site, cond=cond)
            return
        if isinstance(e, L.RefAdd):
            for sub in L.walk(e.value):
                if isinstance(sub, (L.DictLookup, L.HintedLookup)):
                    self._lookup_cost(sub, env, calls, site=f"{site}/refadd")
            self._charge_scalar(e.value, calls)
            return
        if isinstance(e, (L.RecordCtor, L.BinOp, L.UnOp, L.FieldAccess)):
            self._charge_scalar(e, calls)
            return
        if isinstance(e, (L.DictNew, L.RefNew, L.DictIter)):
            return
        raise TypeError(f"cost inference: unknown node {type(e)}")  # pragma: no cover


def _find_lookup(e: L.Expr) -> Optional[Union[L.DictLookup, L.HintedLookup]]:
    for node in L.walk(e):
        if isinstance(node, (L.DictLookup, L.HintedLookup)):
            return node
    return None


def infer_cost(
    expr: L.Expr,
    sigma: CardModel,
    delta: DictCostModel,
    gamma_dict: Optional[GammaDict] = None,
    vectorized: bool = VECTORIZED_DEFAULT,
    net: Optional[NetCostModel] = None,
    sharded_rels: Optional[Tuple[str, ...]] = None,
) -> CostResult:
    """Run the Fig. 8 inference over a whole program.

    ``gamma_dict`` maps dictionary symbols to their (implementation, hinted)
    choice; unmentioned symbols fall back to their ``@ds`` annotation, then to
    ``DEFAULT_DS``.  ``vectorized=False`` recovers the paper's exact per-row
    rules (CPU engine semantics).

    ``net`` prices the *distributed* realization of the program, mirroring
    what ``plan.legalize`` will emit for each dictionary built from a sharded
    base relation (all relations when ``sharded_rels`` is None):

    * aggregate dictionaries (GroupBy/GroupJoin results) pay the per-shard
      partial + shuffle-Exchange: wire traffic (Δ_net) plus the merge
      re-build (Δ insert of the routed partial entries);
    * join indexes (nested/partition dictionaries) pay their *placement* —
      ``broadcast`` all-gathers the build rows (the replicated per-shard
      build is already in the base cost), ``partition`` hash-repartitions
      build and probe rows but builds only 1/n_shards of the dictionary per
      shard, which is credited against the base (full) build charge.  The
      placement comes from ``DictChoice.placement`` so Alg. 1 decides it
      jointly with the implementation.
    """
    eng = _Infer(
        sigma,
        delta,
        gamma_dict or {},
        vectorized=vectorized,
        ordered_off=net is not None and net.n_shards > 1,
    )
    eng.infer(expr, {}, calls=1.0, site="root")
    if net is not None and net.n_shards > 1:
        # probe rows that the co-partitioned realization actually *moves*,
        # mirroring plan.legalize's elisions: a base-relation stream moves
        # iff that relation is sharded; a dict-scan stream probing by the
        # scanned dictionary's whole key is already co-partitioned (or
        # replicated and mask-partitioned) and never moves, otherwise it
        # moves iff the scanned dictionary descends from sharded rows.
        probes: Dict[str, float] = {}
        for dname, n, kind, payload, whole in eng.probe_log:
            if kind == "rel":
                moves = sharded_rels is None or payload in sharded_rels
            else:
                moves = not whole and (
                    sharded_rels is None
                    or bool(payload.build_rels & set(sharded_rels))
                )
            if moves:
                probes[dname] = probes.get(dname, 0.0) + n
        for meta in eng.res.dict_meta.values():
            if sharded_rels is not None and not (
                meta.build_rels & set(sharded_rels)
            ):
                continue
            ds = meta.choice.ds
            size = max(1.0, meta.card)
            if meta.nested:
                placement = meta.choice.placement or "partition"
                if placement == "broadcast":
                    sec = net.broadcast_seconds(meta.elems, meta.lanes)
                else:
                    # move every build and probe row once; the per-shard
                    # build then inserts only 1/n of the rows, credited
                    # against the full build the base walk already charged
                    sec = net.repartition_seconds(meta.elems, meta.lanes)
                    sec += net.repartition_seconds(
                        probes.get(meta.name, 0.0), meta.lanes
                    )
                    full = delta.op_cost(ds, "insert", meta.elems, size, False)
                    sec -= (1.0 - 1.0 / net.n_shards) * full
                eng.res.add(
                    CostItem(
                        "placement", meta.name, ds, placement,
                        meta.elems, size, False, sec,
                    )
                )
                continue
            # each shard holds at most its own elements and at most the full
            # key set; the shuffle moves every per-shard partial entry
            entries = min(meta.elems, meta.card * net.n_shards)
            if entries <= 0:
                continue
            sec = net.shuffle_seconds(entries, meta.lanes)
            sec += delta.op_cost(ds, "insert", entries, size, False)
            eng.res.add(
                CostItem(
                    "exchange", meta.name, ds, "exchange",
                    entries, size, False, sec,
                )
            )
    return eng.res
