"""LLQL → physical-plan lowering.

DBFlex generates specialized C++ from the synthesized LLQL; here the same
role is played by compiling the recognized loop forms (the paper's Fig. 6/7
listings plus their chained compositions) into the explicit physical-plan IR
of ``repro.core.plan``.  ``compile`` is pure translation — no data touched —
so the *same* plan object feeds the single-shard executor
(``repro.exec.engine.execute_plan``), the sharded executor
(``repro.exec.distributed.execute_plan_sharded``), and the cost model.

Recognized forms
----------------
* group-by aggregate (Fig. 6c/6d), with optional filter and hinted insert;
* partitioned FK join build+probe (Fig. 6a/6b), hinted or not — including
  *chains*: loops over previously-joined relations (record-keyed join
  outputs become ``Project`` relations) and index builds over them;
* groupjoin (Fig. 6e/6f);
* scalar aggregation incl. interleaved-lookup form (Fig. 7b);
* dictionary scans (``for g in Agg``) with filter + re-join (TPC-H Q18's
  HAVING + join-back shape);
* selection / projection (§3.3.1–3.3.2).

Anything else falls back to the reference interpreter (slow, correct) with
a warning — never a wrong answer.  This mirrors the paper's scope: its code
generator also only emits the operator forms its frontend produces.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.data.table import Table
from repro.errors import PlanError
from . import llql as L
from . import plan as P
from .cardinality import CardModel
from .cost import DictChoice, GammaDict

# Reserved column names of a materialized dictionary scan (`for g in Agg`):
# `g.key` / `g.val` compile to these columns; extra value lanes get an index
# suffix (`__val__1`, ...).
DICT_KEY = "__key__"
DICT_VAL = "__val__"


# ---------------------------------------------------------------------------
# row-expression compiler
# ---------------------------------------------------------------------------

_BIN = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "&&": lambda a, b: a & b,
    "||": lambda a, b: a | b,
    "min": jnp.minimum,
    "max": jnp.maximum,
}

_UN = {
    "!": lambda v: ~v,
    "-": lambda v: -v,
    "floor": jnp.floor,
}


class _Unsupported(PlanError):
    """An LLQL shape outside the recognized lowering forms.  Subclasses the
    typed :class:`repro.errors.PlanError` (permanent — retry is useless);
    ``run`` still catches it locally to fall back to the interpreter."""


def compile_rowfn_frame(
    e: L.Expr, tables: Dict[str, Table], params: Optional[Dict[str, object]] = None
):
    """Compile a row-level expression over one or more loop variables into a
    columnar jnp value; ``tables`` maps each bound variable to its (aligned)
    table.  ``v.key.a`` reads column ``a`` of v's table; ``v.val`` is the
    dictionary value lane for dict scans and the bag multiplicity otherwise;
    ``v.key`` (whole) is the key column of a dict scan.  ``params`` maps free
    ``L.Param`` names to runtime scalars — traced jit arguments on the cached
    executable path, so rebinding never re-traces."""

    def go(x: L.Expr):
        if isinstance(x, L.Const):
            return x.value
        if isinstance(x, L.Param):
            if params is None or x.name not in params:
                raise _Unsupported(f"unbound parameter ?{x.name}")
            return params[x.name]
        if isinstance(x, L.FieldAccess):
            base = x.rec
            if (
                isinstance(base, L.FieldAccess)
                and base.name == "key"
                and isinstance(base.rec, L.Var)
                and base.rec.name in tables
            ):
                return tables[base.rec.name].col(x.name)
            if isinstance(base, L.Var) and base.name in tables:
                t = tables[base.name]
                if x.name == "val":
                    if DICT_VAL in t.columns:
                        return t.col(DICT_VAL)
                    return t.multiplicity()
                if x.name == "key":
                    if DICT_KEY in t.columns:
                        return t.col(DICT_KEY)
                    raise _Unsupported("whole-row key")
            raise _Unsupported(f"field access {L.pretty(x)}")
        if isinstance(x, L.BinOp):
            return _BIN[x.op](go(x.lhs), go(x.rhs))
        if isinstance(x, L.UnOp):
            return _UN[x.op](go(x.operand))
        raise _Unsupported(f"row expr {type(x).__name__}")

    return go(e)


def compile_rowfn(e: L.Expr, var: str, table: Table):
    """Single-variable form (kept for callers outside the plan executor)."""
    return compile_rowfn_frame(e, {var: table})


# ---------------------------------------------------------------------------
# LLQL → Plan
# ---------------------------------------------------------------------------


def compile(
    expr: L.Expr,
    choices: Optional[GammaDict] = None,
    sigma: Optional[CardModel] = None,
) -> P.Plan:
    """Translate an LLQL program into a physical plan, baking the synthesized
    per-dictionary ``choices`` into the dictionary-producing nodes (symbols
    not covered fall back to their ``@ds`` annotation, then the default).
    Raises ``_Unsupported`` on program shapes outside the recognized forms
    (``execute`` catches it and falls back to the interpreter)."""
    del sigma  # capacity decisions happen at execution time
    choices = dict(choices or {})
    nodes: List[P.Node] = []
    dict_ann: Dict[str, Optional[str]] = {}
    ref_syms: Dict[str, L.Type] = {}
    result: List[Optional[str]] = [None]
    counter = [0]

    def fresh() -> str:
        counter[0] += 1
        return f"%{counter[0] - 1}"

    def choice_of(sym: str) -> DictChoice:
        if sym in choices:
            return choices[sym]
        ann = dict_ann.get(sym)
        return DictChoice(ann) if ann else DictChoice()

    def emit(node: P.Node) -> None:
        nodes.append(node)

    # -- statement level ----------------------------------------------------
    def stmt(x: L.Expr) -> None:
        if isinstance(x, L.Seq):
            stmt(x.first)
            stmt(x.second)
            return
        if isinstance(x, L.Let):
            v = x.value
            if isinstance(v, L.DictNew) and v.key is None:
                dict_ann[x.name] = v.ds
            elif isinstance(v, L.RefNew):
                ref_syms[x.name] = v.type
            elif isinstance(v, L.DictIter) and isinstance(v.dict, L.Var):
                pass  # hintedness rides on HintedUpdate/HintedLookup nodes
            else:
                raise _Unsupported(f"let of {type(v).__name__}")
            stmt(x.body)
            return
        if isinstance(x, L.For):
            loop(x)
            return
        if isinstance(x, L.Var):
            result[0] = x.name
            return
        if isinstance(x, L.Noop):
            return
        raise _Unsupported(f"top-level {type(x).__name__}")

    # -- loop level ---------------------------------------------------------
    def loop(f: L.For) -> None:
        src = f.source
        if isinstance(src, L.Input):
            src_name = src.name
        elif isinstance(src, L.Var) and src.name in dict_ann:
            src_name = src.name  # derived relation or dictionary scan
        else:
            raise _Unsupported("loop over non-input")
        frame = fresh()
        emit(P.Scan(frame, source=src_name, var=f.var))

        body = f.body
        if isinstance(body, L.If) and isinstance(body.els, L.Noop):
            sel = fresh()
            emit(P.Select(sel, source=frame, pred=body.cond))
            frame, body = sel, body.then

        # optional `let rkey = keyexpr in ...` aliases
        key_alias: Dict[str, L.Expr] = {}
        while isinstance(body, L.Let) and not isinstance(
            body.value,
            (L.DictNew, L.RefNew, L.DictIter, L.DictLookup, L.HintedLookup),
        ):
            key_alias[body.name] = body.value
            body = body.body

        def resolve(x: L.Expr) -> L.Expr:
            return L.rewrite(
                x,
                lambda n: key_alias.get(n.name, n) if isinstance(n, L.Var) else n,
            )

        if isinstance(body, (L.DictUpdate, L.HintedUpdate)):
            dict_update(frame, body, resolve)
            return
        if isinstance(body, L.For):
            probe_loop(frame, body, resolve)
            return
        if isinstance(body, L.Let) and isinstance(
            body.value, (L.DictLookup, L.HintedLookup)
        ):
            # Fig. 7b: let ra = Ragg(key) in Covar += {...}
            lk = body.value
            inner = body.body
            if (
                isinstance(inner, L.RefAdd)
                and isinstance(inner.value, L.RecordCtor)
                and isinstance(inner.ref, L.Var)
                and isinstance(lk.dict, L.Var)
            ):
                fields, ops = _record_lanes(tuple(inner.value.fields))
                emit(
                    P.Reduce(
                        inner.ref.name,
                        source=frame,
                        fields=fields,
                        lookup_sym=lk.dict.name,
                        lookup_key=resolve(lk.keyexpr),
                        lookup_var=body.name,
                        ops=ops,
                    )
                )
                return
            raise _Unsupported("lookup-let form")
        if isinstance(body, L.RefAdd) and isinstance(body.ref, L.Var):
            val = resolve(body.value)
            if isinstance(val, L.RecordCtor):
                fields, ops = _record_lanes(tuple(val.fields))
            elif isinstance(val, L.SemiringAgg):
                fields = (("_0", val.contribution()),)
                ops = _norm_ops((val.combine,))
            else:
                fields, ops = (("_0", val),), ()
            emit(P.Reduce(body.ref.name, source=frame, fields=fields, ops=ops))
            return
        raise _Unsupported(f"loop body {type(body).__name__}")

    def dict_update(frame: str, upd, resolve: Callable[[L.Expr], L.Expr]) -> None:
        if not isinstance(upd.dict, L.Var):
            raise _Unsupported("update of non-let-bound dictionary")
        sym = upd.dict.name
        hinted = isinstance(upd, L.HintedUpdate)
        key = resolve(upd.keyexpr)
        val = resolve(upd.value)
        lk = _find_lookup(val)
        if lk is not None and isinstance(lk.dict, L.Var) and lk.dict.name in dict_ann:
            emit(
                P.GroupJoin(
                    sym,
                    source=frame,
                    build=lk.dict.name,
                    keyexpr=key,
                    f_expr=_strip_lookup(val, lk),
                    choice=choice_of(sym),
                    hinted=hinted or isinstance(lk, L.HintedLookup),
                )
            )
        elif isinstance(val, L.DictNew):  # partition/index build
            emit(
                P.HashBuild(
                    sym, source=frame, keyexpr=key, choice=choice_of(sym), hinted=hinted
                )
            )
        else:
            lanes, ops = _value_lanes(val)
            emit(
                P.GroupBy(
                    sym,
                    source=frame,
                    keyexpr=key,
                    values=lanes,
                    choice=choice_of(sym),
                    hinted=hinted,
                    ops=ops,
                )
            )

    def probe_loop(frame: str, nf: L.For, resolve) -> None:
        src = nf.source
        if (
            not isinstance(src, (L.DictLookup, L.HintedLookup))
            or not isinstance(src.dict, L.Var)
            or src.dict.name not in dict_ann
        ):
            raise _Unsupported("nested loop form")
        probe = fresh()
        emit(
            P.HashProbe(
                probe,
                source=frame,
                build=src.dict.name,
                keyexpr=resolve(src.keyexpr),
                inner_var=nf.var,
                hinted=isinstance(src, L.HintedLookup),
            )
        )
        inner = nf.body
        if isinstance(inner, L.If) and isinstance(inner.els, L.Noop):
            sel = fresh()
            emit(P.Select(sel, source=probe, pred=resolve(inner.cond)))
            probe, inner = sel, inner.then
        if isinstance(inner, (L.DictUpdate, L.HintedUpdate)) and isinstance(
            inner.dict, L.Var
        ):
            osym = inner.dict.name
            okey = resolve(inner.keyexpr)
            oval = resolve(inner.value)
            if isinstance(okey, L.RecordCtor):
                # record-keyed join output: a relation downstream loops scan
                emit(P.Project(osym, source=probe, fields=tuple(okey.fields)))
            else:
                lanes, ops = _value_lanes(oval)
                emit(
                    P.GroupBy(
                        osym,
                        source=probe,
                        keyexpr=okey,
                        values=lanes,
                        choice=choice_of(osym),
                        hinted=isinstance(inner, L.HintedUpdate),
                        ops=ops,
                    )
                )
            return
        raise _Unsupported("nested probe body")

    stmt(expr)
    choice_items = tuple((s, choice_of(s)) for s in dict_ann)
    plan_params = tuple(
        (p.name, p.type.kind if isinstance(p.type, L.ScalarT) else str(p.type))
        for p in L.params_of(expr)
    )
    return P.Plan(tuple(nodes), result[0], choice_items, plan_params)


def _lane_contrib(fx: L.Expr) -> L.Expr:
    """A record field's per-row contribution: SemiringAgg lanes contribute
    their payload expression, plain fields contribute themselves."""
    return fx.contribution() if isinstance(fx, L.SemiringAgg) else fx


def _lane_combine(fx: L.Expr) -> str:
    return fx.combine if isinstance(fx, L.SemiringAgg) else "sum"


def _norm_ops(ops: Tuple[str, ...]) -> Tuple[str, ...]:
    """All-sum lanes normalize to the empty tuple — the legacy encoding, so
    sum-only plans keep their structure (fingerprints, describe goldens)."""
    return () if all(o == "sum" for o in ops) else ops


def _value_lanes(
    val: L.Expr,
) -> Tuple[Tuple[Tuple[str, L.Expr], ...], Tuple[str, ...]]:
    """Aggregate lanes + per-lane combine ops of a dictionary value.
    ``record * m`` (the Fig. 6c ``aggfn(r) * r.val`` shape with a record
    aggregate) distributes the multiplicity into each *additive* lane —
    ``min``/``max`` lanes ignore bag multiplicity."""
    if isinstance(val, L.RecordCtor):
        lanes = tuple((a, _lane_contrib(fx)) for a, fx in val.fields)
        ops = _norm_ops(tuple(_lane_combine(fx) for _, fx in val.fields))
        return lanes, ops
    if isinstance(val, L.BinOp) and val.op == "*":
        for rec, mult in ((val.lhs, val.rhs), (val.rhs, val.lhs)):
            if isinstance(rec, L.RecordCtor):
                lanes = []
                ops = []
                for a, fx in rec.fields:
                    op = _lane_combine(fx)
                    cx = _lane_contrib(fx)
                    if op == "sum":
                        cx = L.BinOp("*", cx, mult)
                    lanes.append((a, cx))
                    ops.append(op)
                return tuple(lanes), _norm_ops(tuple(ops))
    if isinstance(val, L.SemiringAgg):
        return (("_0", val.contribution()),), _norm_ops((val.combine,))
    return (("_0", val),), ()


def _value_fields(val: L.Expr) -> Tuple[Tuple[str, L.Expr], ...]:
    """Aggregate lanes of a dictionary value (compat view of
    ``_value_lanes`` without the combine ops)."""
    return _value_lanes(val)[0]


def _record_lanes(
    fields: Tuple[Tuple[str, L.Expr], ...],
) -> Tuple[Tuple[Tuple[str, L.Expr], ...], Tuple[str, ...]]:
    """Scalar-aggregate record lanes (Reduce): contributions + combine ops.
    No multiplicity distribution here — the executor's ``scalar_aggregate``
    applies bag multiplicity to additive lanes itself."""
    lanes = tuple((a, _lane_contrib(fx)) for a, fx in fields)
    ops = _norm_ops(tuple(_lane_combine(fx) for _, fx in fields))
    return lanes, ops


def _find_lookup(e: L.Expr):
    for n in L.walk(e):
        if isinstance(n, (L.DictLookup, L.HintedLookup)):
            return n
    return None


def _strip_lookup(e: L.Expr, lk: L.Expr) -> L.Expr:
    """Remove the multiplicative lookup factor, keeping f(r): rewrites the
    lookup node to the constant 1."""
    return L.rewrite(e, lambda n: L.Const(1.0, L.DOUBLE) if n is lk else n)


# ---------------------------------------------------------------------------
# structural analysis view (compat shim over compile)
# ---------------------------------------------------------------------------

_OPERATOR_NODES = (P.HashBuild, P.GroupBy, P.GroupJoin, P.HashProbe, P.Reduce)


@dataclass
class Program:
    """Flattened operator view of a compiled plan (historic ``analyze`` API:
    ``phases`` are the operator nodes, Scans/Selects/Projects elided)."""

    dict_syms: Dict[str, Optional[str]] = field(default_factory=dict)
    ref_syms: Dict[str, L.Type] = field(default_factory=dict)
    phases: List[object] = field(default_factory=list)
    result: Optional[str] = None


def analyze(e: L.Expr) -> Program:
    plan = compile(e)
    prog = Program()
    for n in L.walk(e):
        if isinstance(n, L.Let):
            if isinstance(n.value, L.DictNew) and n.value.key is None:
                prog.dict_syms[n.name] = n.value.ds
            elif isinstance(n.value, L.RefNew):
                prog.ref_syms[n.name] = n.value.type
    prog.phases = [n for n in plan.nodes if isinstance(n, _OPERATOR_NODES)]
    prog.result = plan.result
    return prog


# ---------------------------------------------------------------------------
# execution entry point
# ---------------------------------------------------------------------------


def execute(
    expr: L.Expr,
    db: Dict[str, Table],
    choices: Optional[GammaDict] = None,
    sigma: Optional[CardModel] = None,
    params: Optional[Dict[str, object]] = None,
):
    """Compile, fuse, and run.  Returns the program result: a ``DictResult``
    for dictionary-valued programs, a ``Table`` for relation results, or a
    dict of scalars for Ref results.  Row-parallel regions are grouped into
    fused ``Pipeline`` nodes under Δ_fuse when Σ is available (DESIGN.md §7
    — fusion is a costed choice, and fused plans are result-identical to
    materialized ones).  Falls back to the interpreter on unrecognized
    structure."""
    from repro.exec import engine as E

    try:
        plan = P.fuse(compile(expr, choices), sigma=sigma)
        return E.execute_plan(plan, db, sigma=sigma, params=params)
    except _Unsupported as why:
        warnings.warn(f"LLQL lowering fell back to interpreter: {why}")
        return _interpret_fallback(expr, db, params=params)


def _interpret_fallback(
    expr: L.Expr, db: Dict[str, Table], params: Optional[Dict[str, object]] = None
):
    from . import interp as I
    import numpy as np

    pydb = {}
    for name, t in db.items():
        mask = np.asarray(t.live_mask())
        cols = {k: np.asarray(v) for k, v in t.columns.items()}
        rows = [
            {k: v[i].item() for k, v in cols.items()}
            for i in range(t.nrows)
            if mask[i]
        ]
        pydb[name] = I.relation(rows, name)
    return I.run(expr, pydb, params=params)
