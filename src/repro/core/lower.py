"""LLQL → vectorized-engine lowering.

DBFlex generates specialized C++ from the synthesized LLQL; here the same
role is played by *tracing*: the recognized loop forms (exactly the paper's
Fig. 6/7 listings) are matched structurally and compiled to the vectorized
operators in ``repro.exec.engine``, parameterized by the ``@ds`` choices the
synthesizer made.  Row-level scalar expressions are compiled to columnar jnp
expressions by ``compile_rowfn``.

Recognized forms
----------------
* group-by aggregate (Fig. 6c/6d), with optional filter and hinted insert;
* partitioned FK join build+probe (Fig. 6a/6b), hinted or not;
* groupjoin (Fig. 6e/6f);
* scalar aggregation incl. interleaved-lookup form (Fig. 7b);
* selection / projection (§3.3.1–3.3.2).

Anything else falls back to the reference interpreter (slow, correct) with
a warning — never a wrong answer.  This mirrors the paper's scope: its code
generator also only emits the operator forms its frontend produces.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.data.table import Table
from repro.dicts import base as dbase
from . import llql as L
from .cardinality import CardModel, key_columns
from .cost import DictChoice, GammaDict


# ---------------------------------------------------------------------------
# row-expression compiler
# ---------------------------------------------------------------------------

_BIN = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "&&": lambda a, b: a & b,
    "||": lambda a, b: a | b,
    "min": jnp.minimum,
    "max": jnp.maximum,
}


def compile_rowfn(e: L.Expr, var: str, table: Table):
    """Compile a row-level expression over loop variable ``var`` into a
    columnar jnp value against ``table``."""

    def go(x: L.Expr):
        if isinstance(x, L.Const):
            return x.value
        if isinstance(x, L.FieldAccess):
            base = x.rec
            if (
                isinstance(base, L.FieldAccess)
                and base.name == "key"
                and isinstance(base.rec, L.Var)
                and base.rec.name == var
            ):
                return table.col(x.name)
            if isinstance(base, L.Var) and base.name == var:
                if x.name == "val":
                    return table.multiplicity()
                if x.name == "key":
                    raise _Unsupported("whole-row key")
            raise _Unsupported(f"field access {L.pretty(x)}")
        if isinstance(x, L.BinOp):
            return _BIN[x.op](go(x.lhs), go(x.rhs))
        if isinstance(x, L.UnOp):
            v = go(x.operand)
            return (~v) if x.op == "!" else (-v)
        raise _Unsupported(f"row expr {type(x).__name__}")

    return go(e)


class _Unsupported(Exception):
    pass


# ---------------------------------------------------------------------------
# structural analysis: flatten the program into phases
# ---------------------------------------------------------------------------


@dataclass
class BuildPhase:
    sym: str
    rel: str
    loopvar: str
    keyexpr: L.Expr
    valexpr: L.Expr  # scalar/record value; DictNew singleton => index build
    pred: Optional[L.Expr] = None
    hinted: bool = False


@dataclass
class ProbeJoinPhase:  # Fig. 6a/6b probe loop (nested For over lookup)
    out_sym: str
    rel: str
    loopvar: str
    inner_var: str
    build_sym: str
    probe_key: L.Expr
    out_key: L.Expr
    valexpr: L.Expr
    pred: Optional[L.Expr] = None
    hinted: bool = False


@dataclass
class GroupJoinPhase:  # Fig. 6e/6f probe: out[k] += f(r) * lookup(build, k)
    out_sym: str
    rel: str
    loopvar: str
    build_sym: str
    keyexpr: L.Expr
    f_expr: L.Expr  # multiplicand not containing the lookup
    pred: Optional[L.Expr] = None
    hinted: bool = False


@dataclass
class ScalarAggPhase:  # RefAdd of a record of row exprs, optional dict lookup
    ref_sym: str
    rel: str
    loopvar: str
    fields: Tuple[Tuple[str, L.Expr], ...]
    lookup_sym: Optional[str] = None  # Fig. 7b: let ra = Ragg(key) in ...
    lookup_key: Optional[L.Expr] = None
    lookup_var: Optional[str] = None
    pred: Optional[L.Expr] = None


@dataclass
class Program:
    dict_syms: Dict[str, Optional[str]] = field(default_factory=dict)  # ds ann
    ref_syms: Dict[str, L.Type] = field(default_factory=dict)
    phases: List[object] = field(default_factory=list)
    result: Optional[str] = None


def analyze(e: L.Expr) -> Program:
    prog = Program()
    hints: Dict[str, str] = {}  # iterator name -> dict sym

    def stmt(x: L.Expr) -> None:
        if isinstance(x, L.Seq):
            stmt(x.first)
            stmt(x.second)
            return
        if isinstance(x, L.Let):
            v = x.value
            if isinstance(v, L.DictNew) and v.key is None:
                prog.dict_syms[x.name] = v.ds
            elif isinstance(v, L.RefNew):
                prog.ref_syms[x.name] = v.type
            elif isinstance(v, L.DictIter) and isinstance(v.dict, L.Var):
                hints[x.name] = v.dict.name
            else:
                raise _Unsupported(f"let of {type(v).__name__}")
            stmt(x.body)
            return
        if isinstance(x, L.For):
            loop(x)
            return
        if isinstance(x, L.Var):
            prog.result = x.name
            return
        if isinstance(x, L.Noop):
            return
        raise _Unsupported(f"top-level {type(x).__name__}")

    def loop(f: L.For) -> None:
        if not isinstance(f.source, L.Input):
            raise _Unsupported("loop over non-input")
        rel, lv = f.source.name, f.var
        body, pred = f.body, None
        if isinstance(body, L.If) and isinstance(body.els, L.Noop):
            pred, body = body.cond, body.then
        # optional `let rkey = keyexpr in ...`
        key_alias: Dict[str, L.Expr] = {}
        while isinstance(body, L.Let) and not isinstance(
            body.value, (L.DictNew, L.RefNew, L.DictIter, L.DictLookup, L.HintedLookup)
        ):
            key_alias[body.name] = body.value
            body = body.body

        def resolve(x: L.Expr) -> L.Expr:
            return L.rewrite(
                x,
                lambda n: key_alias.get(n.name, n) if isinstance(n, L.Var) else n,
            )

        if isinstance(body, (L.DictUpdate, L.HintedUpdate)):
            sym = body.dict.name  # type: ignore[union-attr]
            hinted = isinstance(body, L.HintedUpdate)
            val = resolve(body.value)
            lk = _find_lookup(val)
            if lk is not None and isinstance(lk.dict, L.Var):
                f_expr = _strip_lookup(val, lk)
                prog.phases.append(
                    GroupJoinPhase(
                        out_sym=sym,
                        rel=rel,
                        loopvar=lv,
                        build_sym=lk.dict.name,
                        keyexpr=resolve(body.keyexpr),
                        f_expr=f_expr,
                        pred=pred,
                        hinted=hinted or isinstance(lk, L.HintedLookup),
                    )
                )
            else:
                prog.phases.append(
                    BuildPhase(
                        sym=sym,
                        rel=rel,
                        loopvar=lv,
                        keyexpr=resolve(body.keyexpr),
                        valexpr=val,
                        pred=pred,
                        hinted=hinted,
                    )
                )
            return
        if isinstance(body, L.For):  # nested probe loop (join)
            src = body.source
            if isinstance(src, (L.DictLookup, L.HintedLookup)) and isinstance(
                src.dict, L.Var
            ):
                inner = body.body
                if isinstance(inner, (L.DictUpdate, L.HintedUpdate)):
                    prog.phases.append(
                        ProbeJoinPhase(
                            out_sym=inner.dict.name,  # type: ignore[union-attr]
                            rel=rel,
                            loopvar=lv,
                            inner_var=body.var,
                            build_sym=src.dict.name,
                            probe_key=resolve(src.keyexpr),
                            out_key=resolve(inner.keyexpr),
                            valexpr=resolve(inner.value),
                            pred=pred,
                            hinted=isinstance(src, L.HintedLookup),
                        )
                    )
                    return
            raise _Unsupported("nested loop form")
        if isinstance(body, L.Let) and isinstance(
            body.value, (L.DictLookup, L.HintedLookup)
        ):
            # Fig. 7b: let ra = Ragg(key) in Covar += {...}
            lk = body.value
            inner = body.body
            if isinstance(inner, L.RefAdd) and isinstance(inner.value, L.RecordCtor):
                prog.phases.append(
                    ScalarAggPhase(
                        ref_sym=inner.ref.name,  # type: ignore[union-attr]
                        rel=rel,
                        loopvar=lv,
                        fields=inner.value.fields,
                        lookup_sym=lk.dict.name,  # type: ignore[union-attr]
                        lookup_key=resolve(lk.keyexpr),
                        lookup_var=body.name,
                        pred=pred,
                    )
                )
                return
            raise _Unsupported("lookup-let form")
        if isinstance(body, L.RefAdd):
            val = resolve(body.value)
            fields = (
                val.fields if isinstance(val, L.RecordCtor) else ((("_0"), val),)
            )
            prog.phases.append(
                ScalarAggPhase(
                    ref_sym=body.ref.name,  # type: ignore[union-attr]
                    rel=rel,
                    loopvar=lv,
                    fields=tuple(fields),
                    pred=pred,
                )
            )
            return
        raise _Unsupported(f"loop body {type(body).__name__}")

    stmt(e)
    return prog


def _find_lookup(e: L.Expr):
    for n in L.walk(e):
        if isinstance(n, (L.DictLookup, L.HintedLookup)):
            return n
    return None


def _strip_lookup(e: L.Expr, lk: L.Expr) -> L.Expr:
    """Remove the multiplicative lookup factor, keeping f(r): rewrites the
    lookup node to the constant 1."""
    return L.rewrite(e, lambda n: L.Const(1.0, L.DOUBLE) if n is lk else n)


# ---------------------------------------------------------------------------
# execution of the analyzed program against tables
# ---------------------------------------------------------------------------


def execute(
    expr: L.Expr,
    db: Dict[str, Table],
    choices: Optional[GammaDict] = None,
    sigma: Optional[CardModel] = None,
):
    """Lower and run.  Returns the program result: a ``DictResult`` for
    dictionary-valued programs or a dict of scalars for Ref results.
    Falls back to the interpreter on unrecognized structure."""
    from repro.exec import engine as E

    choices = choices or {}
    try:
        prog = analyze(expr)
    except _Unsupported as why:
        warnings.warn(f"LLQL lowering fell back to interpreter: {why}")
        return _interpret_fallback(expr, db)

    def choice_of(sym: str) -> DictChoice:
        if sym in choices:
            return choices[sym]
        ann = prog.dict_syms.get(sym)
        return DictChoice(ann) if ann else DictChoice()

    def cap_of(sym: str, keyexpr: L.Expr, loopvar: str, rel: str) -> int:
        if sigma is not None:
            cols = key_columns(keyexpr, loopvar)
            d = sigma.dist(rel, cols) if cols else sigma.rel(rel).rows
            return E.capacity_for(choice_of(sym).ds, int(d))
        return E.capacity_for(choice_of(sym).ds, db[rel].nrows)

    env: Dict[str, object] = {}
    refs: Dict[str, jnp.ndarray] = {}
    lanes_of: Dict[str, Tuple[str, ...]] = {}  # record-valued dict lane names

    def sorted_on_key(rel: str, keyexpr: L.Expr, loopvar: str) -> bool:
        t = db[rel]
        cols = key_columns(keyexpr, loopvar)
        return bool(cols) and t.sorted_on[: len(cols)] == tuple(cols)

    for ph in prog.phases:
        t = db[ph.rel]
        if ph.pred is not None:
            t = t.with_mask(compile_rowfn(ph.pred, ph.loopvar, t))
        if isinstance(ph, BuildPhase):
            ch = choice_of(ph.sym)
            keys = compile_rowfn(ph.keyexpr, ph.loopvar, t).astype(jnp.int32)
            srt = sorted_on_key(ph.rel, ph.keyexpr, ph.loopvar)
            cap = cap_of(ph.sym, ph.keyexpr, ph.loopvar, ph.rel)
            if isinstance(ph.valexpr, L.DictNew):  # partition/index build
                env[ph.sym] = (
                    E.build_index(
                        ch.ds, keys, cap, valid=t.mask,
                        assume_sorted=srt and (ch.hinted or ph.hinted),
                    ),
                    ph.rel,
                )
            else:
                if isinstance(ph.valexpr, L.RecordCtor):
                    lanes_of[ph.sym] = tuple(a for a, _ in ph.valexpr.fields)
                    lanes = [
                        jnp.broadcast_to(
                            jnp.asarray(
                                compile_rowfn(fx, ph.loopvar, t), jnp.float32
                            ),
                            (t.nrows,),
                        )
                        for _, fx in ph.valexpr.fields
                    ]
                    vals = jnp.stack(lanes, axis=1)
                else:
                    vals = compile_rowfn(ph.valexpr, ph.loopvar, t)
                    vals = jnp.broadcast_to(
                        jnp.asarray(vals, jnp.float32), (t.nrows,)
                    )
                env[ph.sym] = E.groupby(
                    t, keys, vals, ch.ds, cap,
                    assume_sorted=srt and (ch.hinted or ph.hinted),
                )
        elif isinstance(ph, GroupJoinPhase):
            ch = choice_of(ph.out_sym)
            bch = choice_of(ph.build_sym)
            keys = compile_rowfn(ph.keyexpr, ph.loopvar, t).astype(jnp.int32)
            srt = sorted_on_key(ph.rel, ph.keyexpr, ph.loopvar)
            f_vals = compile_rowfn(ph.f_expr, ph.loopvar, t)
            f_vals = jnp.broadcast_to(jnp.asarray(f_vals, jnp.float32), (t.nrows,))
            build = env[ph.build_sym]
            build = build[0] if isinstance(build, tuple) else build
            cap = cap_of(ph.out_sym, ph.keyexpr, ph.loopvar, ph.rel)
            env[ph.out_sym] = E.groupjoin(
                t, keys, f_vals[:, None], build, ch.ds, cap,
                sorted_probes=srt and (ph.hinted or bch.hinted),
                assume_sorted=srt and ch.hinted,
            )
        elif isinstance(ph, ProbeJoinPhase):
            bch = choice_of(ph.build_sym)
            build, build_rel = env[ph.build_sym]
            keys = compile_rowfn(ph.probe_key, ph.loopvar, t).astype(jnp.int32)
            srt = sorted_on_key(ph.rel, ph.probe_key, ph.loopvar)
            joined = E.fk_join(
                t, keys, db[build_rel], build,
                take=list(db[build_rel].names()),
                sorted_probes=srt and (ph.hinted or bch.hinted),
                prefix=f"{ph.inner_var}_",
            )
            env[ph.out_sym] = ("relation", joined, ph)
        elif isinstance(ph, ScalarAggPhase):
            cols = {}
            if ph.lookup_sym is not None:
                d = env[ph.lookup_sym]
                d = d[0] if isinstance(d, tuple) else d
                keys = compile_rowfn(ph.lookup_key, ph.loopvar, t).astype(jnp.int32)
                srt = sorted_on_key(ph.rel, ph.lookup_key, ph.loopvar)
                lch = choice_of(ph.lookup_sym)
                vals, found = E.lookup_dict(
                    d, keys, valid=t.mask, sorted_probes=srt and lch.hinted
                )
                t = t.with_mask(found)
                # expose looked-up record fields as columns <var>.<field>
                # field order: the groupby value arity order — callers use
                # positional .get on the record; we map by position.
                cols = {"__lookup__": vals}
            total = {}
            lk_lanes = lanes_of.get(ph.lookup_sym or "", ("m", "c", "c_c"))
            for i, (fname, fexpr) in enumerate(ph.fields):
                col = _compile_scalar_field(fexpr, ph, t, cols, lk_lanes)
                total[fname] = E.scalar_aggregate(t, col)[0]
            refs[ph.ref_sym] = total
        else:  # pragma: no cover
            raise AssertionError(ph)

    if prog.result is None:
        # program returns a ref (scalar aggregate record)
        if len(refs) == 1:
            return next(iter(refs.values()))
        return refs
    out = refs.get(prog.result, env.get(prog.result))
    return out


def _compile_scalar_field(
    fexpr: L.Expr, ph: ScalarAggPhase, t: Table, cols, lane_names=("m", "c", "c_c")
):
    """Compile one field of a scalar-agg record; lookup-value field accesses
    (``ra.m`` etc.) resolve into the looked-up value lanes by the lane names
    recorded when the probed dictionary was built (Fig. 7b's Ragg record)."""
    lanes: Dict[str, int] = {}
    if ph.lookup_var is not None:
        lanes = {nm: i for i, nm in enumerate(lane_names)}

    def go(x: L.Expr):
        if (
            isinstance(x, L.FieldAccess)
            and isinstance(x.rec, L.Var)
            and x.rec.name == ph.lookup_var
        ):
            return cols["__lookup__"][:, lanes[x.name]]
        if isinstance(x, L.BinOp):
            return _BIN[x.op](go(x.lhs), go(x.rhs))
        if isinstance(x, L.UnOp):
            return -go(x.operand)
        if isinstance(x, L.Const):
            return x.value
        return compile_rowfn(x, ph.loopvar, t)

    return jnp.asarray(go(fexpr), jnp.float32)


def _interpret_fallback(expr: L.Expr, db: Dict[str, Table]):
    from . import interp as I
    import numpy as np

    pydb = {}
    for name, t in db.items():
        mask = np.asarray(t.live_mask())
        cols = {k: np.asarray(v) for k, v in t.columns.items()}
        rows = [
            {k: v[i].item() for k, v in cols.items()}
            for i in range(t.nrows)
            if mask[i]
        ]
        pydb[name] = I.relation(rows, name)
    return I.run(expr, pydb)
