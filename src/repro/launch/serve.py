"""Production serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
        --requests 16 --slots 4 [--ckpt-dir /ckpts/run1]

Restores bf16 weights from the newest committed checkpoint when one exists
(elastic: any saved mesh restores onto the current devices), otherwise
initializes randomly (demo mode), then runs the continuous-batching decode
loop and prints aggregate throughput.
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax

    from repro.models.registry import get_model_by_name
    from repro.serve.serve_loop import Request, Server
    from repro.train import checkpoint as ckpt

    model = get_model_by_name(args.arch, reduced=args.reduced)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        like = {"params": model.init_shapes()}
        tree, meta = ckpt.restore(args.ckpt_dir, like)
        params = tree["params"]
        print(f"[serve] restored step {meta['step']} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.PRNGKey(0))
        print("[serve] no checkpoint — random weights (demo mode)")
    # serving runs bf16 weights (same policy as the dry-run serve cells)
    import jax.numpy as jnp

    params = jax.tree.map(
        lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a, params
    )

    srv = Server(
        model, params, batch_slots=args.slots, cache_len=args.cache_len,
        eos=-1, temperature=args.temperature,
    )
    for i in range(args.requests):
        srv.submit(Request(rid=i, prompt=[1 + i % 7, 2, 3], max_new=args.max_new))
    t0 = time.perf_counter()
    done = srv.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(
        f"[serve] {len(done)} requests, {toks} tokens, {dt:.2f}s "
        f"({toks/dt:.1f} tok/s aggregate over {args.slots} slots, "
        f"{srv.steps_run} decode steps)"
    )


if __name__ == "__main__":
    main()
