import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis per (arch × shape × mesh) — EXPERIMENTS.md §Roofline.

Three terms per cell, all in seconds/step on the v5e target:

    compute    = FLOPs_per_device / PEAK_FLOPS
    memory     = HBM_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

Two estimates are reported side by side and cross-checked:

* ``hlo``      — from the compiled dry-run artifact.  XLA's cost_analysis
  counts a ``scan`` body ONCE, so the per-layer-block step is lowered
  separately (grad-of-block for train, block-apply for serve) and scaled by
  the trip count; inner time-chunk scans (chunked attention / SSM) are
  corrected with their analytic per-chunk flops (the residual undercount is
  measured and reported as ``hlo_coverage``).
* ``analytic`` — closed-form flops/bytes from the architecture equations
  (matmul-exact; the headline numbers).

MODEL_FLOPS = 6·N_active·D is reported with MODEL_FLOPS/HLO_FLOPs — the
"useful fraction" that exposes remat recompute and dispatch overheads.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
import argparse
import dataclasses
import functools
import json
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.config import SHAPES, ArchConfig, ShapeSpec, shape as shape_by_name
from repro.models.registry import Model, get_model
from repro.sharding import partition
from repro.sharding.params import (
    batch_shardings,
    cache_shardings,
    layout_overrides,
    opt_state_shardings,
    param_shardings,
)
from repro.train.optimizer import OptConfig, init_state
from . import dryrun as dr
from . import hlo_analysis
from .mesh import make_production_mesh

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

ATTN_CHUNK = 1024  # kernels.ref.flash_attention_chunked default


# ---------------------------------------------------------------------------
# analytic FLOPs (forward, per device, whole step)
# ---------------------------------------------------------------------------


def _attn_tkv(kind: str, T: int, causal: bool = True) -> float:
    if kind == "decode":
        return float(T)
    return T / 2 if causal else float(T)


def analytic_flops(cfg: ArchConfig, spec: ShapeSpec, n_devices: int) -> Dict[str, float]:
    """Closed-form FLOPs per device for one step (train: fwd+bwd+remat-fwd)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    B, T = spec.global_batch, spec.seq_len
    kind = spec.kind
    tokens = B * (1 if kind == "decode" else T)

    def attn_flops_tok(Tkv):
        proj = 2 * d * hd * (2 * H + 2 * Hkv)
        core = 4 * H * hd * Tkv
        return proj + core

    def dense_mlp_tok():
        return 6 * d * ff if cfg.mlp == "swiglu" else 4 * d * ff

    def moe_tok():
        active = cfg.moe_top_k + (1 if cfg.moe_shared_expert else 0)
        return active * 6 * d * ff + 2 * d * cfg.moe_experts

    def mamba_tok():
        di = cfg.mamba_expand * d
        s = cfg.mamba_d_state
        rank = max(1, d // 16)
        return (
            2 * d * 2 * di + 2 * cfg.mamba_conv * di + 2 * di * (rank + 2 * s)
            + 2 * rank * di + 6 * di * s + 2 * di * d
        )

    def rwkv_tok():
        hs = cfg.rwkv_head_size
        c = cfg.scan_chunk
        tmix = 12 * d * d + d * (4 * c + 4 * hs)
        cmix = 4 * d * ff + 2 * d * d
        return tmix + cmix

    Tkv = _attn_tkv(kind, T)
    per_tok = 0.0
    parts: Dict[str, float] = {}
    if cfg.model_kind == "decoder":
        ffn = moe_tok() if (cfg.moe_experts and cfg.moe_every == 1) else dense_mlp_tok()
        per_tok = cfg.n_layers * (attn_flops_tok(Tkv) + ffn)
        parts["attn_core"] = cfg.n_layers * 4 * H * hd * Tkv * tokens
    elif cfg.model_kind == "encdec":
        enc_tok = cfg.enc_layers * (attn_flops_tok(cfg.enc_seq / 2) + dense_mlp_tok())
        dec_tok = cfg.n_layers * (
            attn_flops_tok(Tkv) + attn_flops_tok(cfg.enc_seq) + dense_mlp_tok()
        )
        enc_tokens = B * cfg.enc_seq if kind != "decode" else 0
        parts["encoder"] = enc_tok * enc_tokens
        per_tok = dec_tok
    elif cfg.model_kind == "rwkv":
        per_tok = cfg.n_layers * rwkv_tok()
    elif cfg.model_kind == "jamba":
        n_attn = cfg.n_layers // cfg.attn_period
        n_mamba = cfg.n_layers - n_attn
        n_moe = cfg.n_layers // 2
        n_dense = cfg.n_layers - n_moe
        Tkv_j = min(Tkv, cfg.long_window) if T > 32768 else Tkv
        per_tok = (
            n_attn * attn_flops_tok(Tkv_j)
            + n_mamba * mamba_tok()
            + n_moe * (cfg.moe_top_k * 6 * d * ff + 2 * d * cfg.moe_experts)
            + n_dense * dense_mlp_tok()
        )
    head = 2 * d * V
    fwd = (per_tok + head) * tokens + parts.get("encoder", 0.0)
    mult = 4.0 if kind == "train" else 1.0  # bwd ×2 + remat re-forward ×1
    total = fwd * mult
    return {
        "fwd_flops_global": fwd,
        "total_flops_global": total,
        "total_flops_per_device": total / n_devices,
        "model_flops_6nd": 6.0 * _active_params(cfg) * tokens,
    }


def _active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (dense count; MoE counts active experts
    + router + shared)."""
    d, ff = cfg.d_model, cfg.d_ff
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hd * (2 * H + 2 * Hkv)
    if cfg.moe_experts and cfg.moe_every == 1:
        ffn = (cfg.moe_top_k + (1 if cfg.moe_shared_expert else 0)) * 3 * d * ff
        ffn += d * cfg.moe_experts
    else:
        ffn = (3 if cfg.mlp == "swiglu" else 2) * d * ff
    per_layer = attn + ffn
    if cfg.model_kind == "jamba":
        di = cfg.mamba_expand * d
        s = cfg.mamba_d_state
        rank = max(1, d // 16)
        mamba = 2 * d * 2 * di / 2 + di * (rank + 2 * s) + rank * di + di * d
        n_attn = cfg.n_layers // cfg.attn_period
        n_moe = cfg.n_layers // 2
        per = (
            n_attn * attn
            + (cfg.n_layers - n_attn) * mamba
            + n_moe * cfg.moe_top_k * 3 * d * ff
            + (cfg.n_layers - n_moe) * 3 * d * ff
        )
        return per + cfg.padded_vocab * d
    if cfg.model_kind == "rwkv":
        per_layer = 6 * d * d + (2 * d * ff + d * d)
    total = cfg.n_layers * per_layer + cfg.padded_vocab * d
    if cfg.model_kind == "encdec":
        total += cfg.enc_layers * (attn + 2 * d * ff)
    return total


# ---------------------------------------------------------------------------
# analytic HBM bytes (per device, per step)
# ---------------------------------------------------------------------------


def analytic_bytes(
    cfg: ArchConfig, spec: ShapeSpec, mesh, n_params: int
) -> Dict[str, float]:
    n_dev = mesh.devices.size
    n_model = mesh.shape.get("model", 1)
    dp = n_dev // n_model
    # params are sharded over every axis (TP × fsdp)
    p_dev = n_params / n_dev
    B, T = spec.global_batch, spec.seq_len
    b_loc = max(B // dp, 1)
    if spec.kind == "train":
        # bf16 reads ×3 (fwd, bwd, remat re-fwd), f32 grad write, Adam m/v r+w
        param_traffic = p_dev * (3 * 2 + 4 + 4 * 4)
        act = 6 * cfg.n_layers * b_loc * (T / max(n_model, 1)) * cfg.d_model * 2
        cache = 0.0
    elif spec.kind == "prefill":
        param_traffic = p_dev * 2
        act = 4 * cfg.n_layers * b_loc * T * cfg.d_model * 2 / max(n_model, 1)
        cache = 0.0
    else:  # decode: read the whole resident cache every step
        param_traffic = p_dev * 2
        act = 0.0
        cache = _cache_bytes_per_device(cfg, spec, mesh)
    total = param_traffic + act + cache
    return {
        "param_traffic": param_traffic,
        "activation_traffic": act,
        "cache_traffic": cache,
        "total_bytes_per_device": total,
    }


def _cache_bytes_per_device(cfg: ArchConfig, spec: ShapeSpec, mesh) -> float:
    model = get_model(cfg)
    shapes = jax.eval_shape(
        lambda: model.mod.init_cache(cfg, spec.global_batch, spec.seq_len)
    )
    total = sum(
        int(jnp.dtype(x.dtype).itemsize) * int(functools.reduce(lambda a, b: a * b, x.shape, 1))
        for x in jax.tree.leaves(shapes)
    )
    return total / mesh.devices.size


# ---------------------------------------------------------------------------
# per-block HLO artifact (scan-once correction)
# ---------------------------------------------------------------------------


def _blocks_cfg(cfg: ArchConfig, n: int) -> Tuple[ArchConfig, int]:
    """A config with exactly ``n`` *unrolled* scan blocks; returns
    (cfg_n, n_blocks_full)."""
    if cfg.model_kind == "jamba":
        return (
            dataclasses.replace(
                cfg, n_layers=n * cfg.attn_period, scan_unroll=True
            ),
            cfg.n_layers // cfg.attn_period,
        )
    if cfg.model_kind == "encdec":
        return (
            dataclasses.replace(cfg, n_layers=n, enc_layers=n, scan_unroll=True),
            cfg.n_layers,  # enc+dec blocks paired per unit
        )
    return dataclasses.replace(cfg, n_layers=n, scan_unroll=True), cfg.n_layers


def _lower_cfg_step(cfg_n: ArchConfig, spec: ShapeSpec, multi_pod: bool):
    model = get_model(cfg_n)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = OptConfig(moments_dtype="bfloat16")  # match dryrun
    with partition.use_mesh(
        mesh, overrides=layout_overrides(model.cfg, spec.global_batch, mesh)
    ):
        param_shapes = model.init_shapes()
        if spec.kind != "train":
            param_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
                ),
                param_shapes,
            )
        p_sh = param_shardings(mesh, param_shapes)
        inputs = model.input_specs(spec)
        if spec.kind == "train":
            opt_shapes = jax.eval_shape(lambda: init_state(param_shapes, opt_cfg))
            o_sh = opt_state_shardings(mesh, opt_shapes)
            b_sh = batch_shardings(mesh, inputs)
            step = dr.make_train_step(model, opt_cfg)
            compiled = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, inputs).compile()
        elif spec.kind == "prefill":
            b_sh = batch_shardings(mesh, inputs)
            compiled = jax.jit(
                dr.make_prefill_step(model), in_shardings=(p_sh, b_sh)
            ).lower(param_shapes, inputs).compile()
        else:
            c_sh = cache_shardings(mesh, inputs["cache"])
            t_sh = batch_shardings(mesh, inputs["token"])
            compiled = jax.jit(
                dr.make_serve_step(model), in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(None, c_sh), donate_argnums=(1,),
            ).lower(param_shapes, inputs["cache"], inputs["token"]).compile()
    flops, byts = hlo_analysis.flops_bytes(compiled)
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return {
        "flops": flops,
        "bytes": byts,
        "collective_bytes": float(coll.total_bytes),
    }


def block_artifact(
    arch: str, spec: ShapeSpec, multi_pod: bool = False
) -> Dict[str, float]:
    """Per-block costs via the unrolled-delta method: lower 2-block and
    1-block models with layers UNROLLED (no scan — every op counted), take
    the difference.  Per-step costs (embed, head, loss, optimizer, gradient
    exchange of non-layer params) cancel exactly; what remains is one
    block's fwd(+bwd+remat) flops/bytes/collectives under the production
    sharding."""
    cfg = configs.get(arch)
    cfg1, n_blocks = _blocks_cfg(cfg, 1)
    cfg2, _ = _blocks_cfg(cfg, 2)
    a1 = _lower_cfg_step(cfg1, spec, multi_pod)
    a2 = _lower_cfg_step(cfg2, spec, multi_pod)
    return {
        "n_blocks": n_blocks,
        "flops": a2["flops"] - a1["flops"],
        "bytes": a2["bytes"] - a1["bytes"],
        "collective_bytes": a2["collective_bytes"] - a1["collective_bytes"],
        "per_step_overhead_flops": 2 * a1["flops"] - a2["flops"],
    }


# ---------------------------------------------------------------------------
# the roofline report for one cell
# ---------------------------------------------------------------------------

ADVICE = {
    "compute": "raise arithmetic efficiency: larger per-chip batch/seq tiles, "
    "fuse attention (Pallas kernel on real TPU), drop remat recompute where "
    "memory allows",
    "memory": "cut HBM traffic: bf16/int8 weights & cache, larger fused "
    "blocks so activations stay in VMEM, quantized KV cache for decode",
    "collective": "overlap/shrink collectives: int8 gradient exchange, "
    "ring-overlapped all-gather matmuls, hierarchical (intra-pod-first) "
    "reductions, rebalance TP vs DP axes",
}


def roofline_cell(
    arch: str,
    shape_name: str,
    dry_result: Optional[Dict[str, Any]] = None,
    multi_pod: bool = False,
    with_block_correction: bool = True,
) -> Dict[str, Any]:
    cfg = configs.get(arch)
    spec = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = get_model(cfg)
    ok, why = model.supports(spec)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    af = analytic_flops(cfg, spec, n_dev)
    n_params = sum(
        int(functools.reduce(lambda a, b: a * b, x.shape, 1))
        for x in jax.tree.leaves(model.init_shapes())
    )
    ab = analytic_bytes(cfg, spec, mesh, n_params)

    out: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
        "n_params": n_params,
        "analytic": {
            "compute_s": af["total_flops_per_device"] / PEAK_FLOPS,
            "memory_s": ab["total_bytes_per_device"] / HBM_BW,
            "flops_per_device": af["total_flops_per_device"],
            "bytes_per_device": ab["total_bytes_per_device"],
            "model_flops_6nd": af["model_flops_6nd"],
        },
    }

    # ---- HLO terms (scan-corrected)
    if dry_result is not None and dry_result.get("status") == "ok":
        hlo_f = dry_result["hlo_flops_per_device"]
        hlo_b = dry_result["hlo_bytes_per_device"]
        hlo_c = dry_result["collectives"]["total_bytes"]
        corr = None
        if with_block_correction:
            try:
                blk = block_artifact(arch, spec, multi_pod=multi_pod)
                nb = blk["n_blocks"]
                corr = {
                    # deltas clamp at 0: XLA occasionally optimizes the
                    # 2-block lowering below the 1-block one
                    "flops": hlo_f + (nb - 1) * max(blk["flops"], 0.0),
                    "bytes": hlo_b + (nb - 1) * max(blk["bytes"], 0.0),
                    "collective_bytes": hlo_c
                    + (nb - 1) * max(blk["collective_bytes"], 0.0),
                    "n_blocks": nb,
                }
            except Exception as e:  # noqa: BLE001
                corr = {"error": repr(e)[:200]}
        hf = corr["flops"] if corr and "flops" in corr else hlo_f
        hb = corr["bytes"] if corr and "bytes" in corr else hlo_b
        hc = corr["collective_bytes"] if corr and "flops" in corr else hlo_c
        out["hlo"] = {
            "compute_s": hf / PEAK_FLOPS,
            "memory_s": hb / HBM_BW,
            "collective_s": hc / ICI_BW,
            "flops_per_device": hf,
            "bytes_per_device": hb,
            "collective_bytes_per_device": hc,
            "scan_correction": corr,
            "useful_fraction": (
                af["model_flops_6nd"] / (hf * n_dev) if hf else None
            ),
            "hlo_coverage": hf * n_dev / max(af["total_flops_global"], 1.0),
        }
        coll_s = hc / ICI_BW
    else:
        coll_s = 0.0
        out["hlo"] = None

    terms = {
        "compute": out["analytic"]["compute_s"],
        "memory": out["analytic"]["memory_s"],
        "collective": coll_s,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    out["terms_s"] = terms
    out["dominant"] = dominant
    out["roofline_fraction"] = (
        out["analytic"]["compute_s"] / step_s if step_s > 0 else None
    )
    out["mfu_upper_bound"] = (
        af["model_flops_6nd"] / n_dev / PEAK_FLOPS / step_s if step_s > 0 else None
    )
    out["advice"] = ADVICE[dominant]
    return out


def run_all(dryrun_path: str = "var/dryrun.json", out_path: str = "var/roofline.json"):
    with open(dryrun_path) as f:
        dres = json.load(f)
    index = {(r["arch"], r["shape"], r["mesh"]): r for r in dres}
    rows = []
    for arch in configs.ARCH_IDS:
        for spec in SHAPES:
            key = (arch, spec.name, "16x16")
            rows.append(
                roofline_cell(arch, spec.name, dry_result=index.get(key))
            )
            with open(out_path, "w") as f:
                json.dump(rows, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in rows)
    print(f"roofline: {n_ok} cells -> {out_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--dryrun", default="var/dryrun.json")
    ap.add_argument("--out", default="var/roofline.json")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        run_all(args.dryrun, args.out)
    else:
        res = roofline_cell(args.arch, args.shape)
        print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
