import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration probe: per-layer collective breakdown for one cell.

Lowers the unrolled 1-block vs 2-block steps (same method as the roofline's
delta) and prints the per-block collective ops by kind/shape — the profile
that drives the §Perf hypothesis loop.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch granite-20b --shape train_4k
"""
import argparse
import collections
import re
from typing import Dict, Tuple

from repro.models.config import shape as shape_by_name
from . import hlo_analysis, roofline

_SHAPE = re.compile(r"(\w+\[[\d,]*\])")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    profile_cell(args.arch, args.shape, args.multi_pod)


def profile_cell(arch: str, shape_name: str, multi_pod: bool = False):
    import jax
    import jax.numpy as jnp

    from repro import configs

    spec = shape_by_name(shape_name)
    cfg = configs.get(arch)
    hists = {}
    for n in (1, 2):
        cfg_n, _ = roofline._blocks_cfg(cfg, n)
        compiled = _compile(cfg_n, spec, multi_pod)
        hist = collections.Counter()
        byts = collections.Counter()
        for line in compiled.as_text().splitlines():
            s = line.strip()
            for kind in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"):
                if f" {kind}(" in s or s.startswith(kind + "("):
                    shape_str = s.split("=", 1)[1].split(kind + "(")[0] if "=" in s else s
                    m = _SHAPE.search(shape_str)
                    key = (kind, m.group(1) if m else "?")
                    hist[key] += 1
                    byts[key] += hlo_analysis.shape_bytes(shape_str)
                    break
        hists[n] = (hist, byts)
    h1, b1 = hists[1]
    h2, b2 = hists[2]
    print(f"== per-block collective delta for {arch} × {shape_name}"
          f" ({'2x16x16' if multi_pod else '16x16'}):")
    rows = []
    for key in set(h2) | set(h1):
        dc = h2.get(key, 0) - h1.get(key, 0)
        db = b2.get(key, 0) - b1.get(key, 0)
        if dc or db:
            rows.append((db, dc, key))
    total = 0
    for db, dc, (kind, shp) in sorted(rows, reverse=True):
        print(f"  {dc:+3d}x {kind:<20} {shp:<28} {db/2**20:+9.1f} MiB")
        total += db
    print(f"  == per-block delta total: {total/2**20:.1f} MiB/device")
    print("== per-step base (1-block program):")
    for key, b in sorted(b1.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {h1[key]:3d}x {key[0]:<20} {key[1]:<28} {b/2**20:9.1f} MiB")


def _compile(cfg_n, spec, multi_pod):
    import jax
    import jax.numpy as jnp

    from repro.models.registry import get_model
    from repro.sharding import partition
    from repro.sharding.params import (
        batch_shardings, cache_shardings, layout_overrides,
        opt_state_shardings, param_shardings,
    )
    from repro.train.optimizer import OptConfig, init_state
    from . import dryrun as dr
    from .mesh import make_production_mesh

    model = get_model(cfg_n)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = OptConfig(moments_dtype="bfloat16")
    with partition.use_mesh(
        mesh, overrides=layout_overrides(model.cfg, spec.global_batch, mesh)
    ):
        param_shapes = model.init_shapes()
        if spec.kind != "train":
            param_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
                ),
                param_shapes,
            )
        p_sh = param_shardings(mesh, param_shapes)
        inputs = model.input_specs(spec)
        if spec.kind == "train":
            opt_shapes = jax.eval_shape(lambda: init_state(param_shapes, opt_cfg))
            o_sh = opt_state_shardings(mesh, opt_shapes)
            b_sh = batch_shardings(mesh, inputs)
            return jax.jit(
                dr.make_train_step(model, opt_cfg),
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, inputs).compile()
        if spec.kind == "prefill":
            b_sh = batch_shardings(mesh, inputs)
            return jax.jit(
                dr.make_prefill_step(model), in_shardings=(p_sh, b_sh)
            ).lower(param_shapes, inputs).compile()
        c_sh = cache_shardings(mesh, inputs["cache"])
        t_sh = batch_shardings(mesh, inputs["token"])
        return jax.jit(
            dr.make_serve_step(model), in_shardings=(p_sh, c_sh, t_sh),
            out_shardings=(None, c_sh), donate_argnums=(1,),
        ).lower(param_shapes, inputs["cache"], inputs["token"]).compile()


if __name__ == "__main__":
    main()
