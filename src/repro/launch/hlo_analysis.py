"""Post-SPMD HLO analysis: collective traffic + helpers for the roofline.

Parses ``compiled.as_text()`` (optimized, partitioned HLO) and sums the
result-shape bytes of every collective op.  Notes:

* collective bytes are *per participating device* (result shape is already
  the per-device shard) — matching the roofline's "bytes crossing this
  chip's links" denominator;
* ops inside a ``while`` body (scan over layers) appear ONCE in the text;
  the roofline layer applies trip-count corrections (see launch.roofline);
* ``replica_groups`` cardinality is captured so traffic can be attributed
  to mesh axes (|group| 2 → "pod", 16 → "data"/"model").
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
# iota form: replica_groups=[n_groups,group_size]<=[N]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like ``f32[8,128]`` or a tuple
    ``(f32[8], f32[8])``."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    bytes_by_kind: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    bytes_by_group_size: Dict[int, int] = field(default_factory=lambda: defaultdict(int))
    total_bytes: int = 0

    def merge_scaled(self, other: "CollectiveStats", scale: float) -> None:
        for k, v in other.counts.items():
            self.counts[k] += int(v * scale)
        for k, v in other.bytes_by_kind.items():
            self.bytes_by_kind[k] += int(v * scale)
        for k, v in other.bytes_by_group_size.items():
            self.bytes_by_group_size[k] += int(v * scale)
        self.total_bytes += int(other.total_bytes * scale)

    def summary(self) -> Dict[str, object]:
        return {
            "total_bytes": self.total_bytes,
            "by_kind": dict(self.bytes_by_kind),
            "counts": dict(self.counts),
            "by_group_size": {str(k): v for k, v in self.bytes_by_group_size.items()},
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    out = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-shape = lhs of " = kind(", e.g. `%x = f32[8]{0} all-reduce(...)`
        for kind in _COLLECTIVES:
            token = f" {kind}("
            if token in s or s.startswith(kind + "("):
                lhs = s.split("=", 1)[0] if "=" in s else ""
                shape_part = s.split("=", 1)[1] if "=" in s else s
                shape_str = shape_part.split(kind + "(")[0]
                b = shape_bytes(shape_str)
                out.counts[kind] += 1
                out.bytes_by_kind[kind] += b
                out.total_bytes += b
                gi = _GROUPS_IOTA_RE.search(s)
                if gi:
                    out.bytes_by_group_size[int(gi.group(2))] += b
                else:
                    g = _GROUPS_RE.search(s)
                    if g:
                        gsize = len(
                            [x for x in g.group(1).split(",") if x.strip() != ""]
                        )
                        out.bytes_by_group_size[gsize] += b
                break
    return out


def flops_bytes(compiled) -> Tuple[float, float]:
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if isinstance(ma, (list, tuple)):  # pragma: no cover
        ma = ma[0]
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "code_bytes": float(ma.generated_code_size_in_bytes),
        "peak_per_device_gib": (
            float(ma.argument_size_in_bytes)
            + float(ma.output_size_in_bytes)
            + float(ma.temp_size_in_bytes)
            - float(ma.alias_size_in_bytes)
        )
        / 2**30,
    }
