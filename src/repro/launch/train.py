"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 1000 --ckpt-dir /ckpts/run1 [--multi-pod] [--compress]

On the pod fleet this process runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); in this container it runs the same code
on the host mesh.  Fault tolerance: the Trainer resumes from the newest
committed checkpoint; the data stream position rides in checkpoint meta, so
a restarted run is bit-identical to an uninterrupted one.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):  # pragma: no cover - fleet only
        import jax

        jax.distributed.initialize()

    from repro.models.registry import get_model_by_name
    from repro.data.lm_data import StreamConfig
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import Trainer, TrainConfig

    model = get_model_by_name(args.arch, reduced=args.reduced)
    scfg = StreamConfig(
        vocab=model.cfg.vocab, global_batch=args.global_batch,
        seq_len=args.seq_len, seed=0,
    )
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt_dir, log_every=10,
        opt=OptConfig(
            lr=args.lr, warmup_steps=max(args.steps // 50, 10),
            total_steps=args.steps, compress=args.compress,
        ),
    )
    t = Trainer(model, tcfg, scfg)
    start = t.restore_or_init()
    print(f"[launch.train] {args.arch} from step {start}")
    t.run()


if __name__ == "__main__":
    main()
