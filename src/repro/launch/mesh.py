"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is 16×16 = 256 chips (data × model); the multi-pod mesh prepends a 2-wide
"pod" axis (2 × 256 = 512 chips) — data parallelism across pods, with the
gradient all-reduce over "pod" being the inter-pod traffic the dry-run
must prove out.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this process actually has (tests / examples)."""
    n = len(jax.devices())
    return compat.make_mesh((n,), ("data",))
