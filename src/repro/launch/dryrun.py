import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Run as

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out var/dryrun.json

Per cell this proves, without any TPU:
  * the sharding assignment is coherent (lower() succeeds),
  * the partitioned program compiles (SPMD partitioner finds a schedule),
  * the per-device memory fits (memory_analysis),
and records flops / bytes / collective traffic for §Roofline.

train/prefill shapes lower ``train_step`` / ``prefill_step``; decode shapes
lower ``serve_step`` (one token against a full-length cache).  Cells marked
unsupported (long_500k × full-attention archs) are recorded as skipped —
that skip matrix is part of the deliverable (DESIGN.md §5).
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.config import SHAPES, ShapeSpec, shape as shape_by_name
from repro.models.registry import Model, get_model
from repro.sharding import partition
from repro.sharding.params import (
    batch_shardings,
    cache_shardings,
    layout_overrides,
    opt_state_shardings,
    param_shardings,
)
from repro.train.optimizer import OptConfig, apply_updates, init_state
from . import hlo_analysis
from .mesh import make_production_mesh


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(model: Model, opt_cfg: OptConfig, microbatches: int = 1):
    """Full training step; ``microbatches>1`` = gradient accumulation over
    batch slices (scan) — the standard activation-memory lever when a cell's
    per-device batch doesn't fit alongside the residual stacks."""

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(
                    (microbatches, a.shape[0] // microbatches) + a.shape[1:]
                ),
                batch,
            )

            def acc(carry, b):
                l, g = jax.value_and_grad(model.loss_fn)(params, b)
                loss_a, grads_a = carry
                return (
                    loss_a + l / microbatches,
                    jax.tree.map(lambda x, y: x + y / microbatches, grads_a, g),
                ), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zero_g), mb)
        params, opt_state, metrics = apply_updates(params, opt_state, grads, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.model_kind == "encdec":
            logits, _ = model.mod.forward(cfg, params, batch["tokens"], batch["frames"])
        elif cfg.vision_tokens:
            logits, _ = model.mod.forward(
                cfg, params, batch["tokens"], patch_embeds=batch["patches"]
            )
        else:
            logits, _ = model.mod.forward(cfg, params, batch["tokens"])
        # serving prefill returns the last-position logits (next-token)
        return logits[:, -1]

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)

    return serve_step


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    opt_cfg: Optional[OptConfig] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    t0 = time.time()
    cfg = configs.get(arch)
    model = get_model(cfg)
    spec = shape_by_name(shape_name)
    ok, why = model.supports(spec)
    if not ok:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped", "reason": why,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    # production-scale Adam keeps bf16 moments (f32 math, bf16 storage) —
    # f32 moments alone exceed HBM for the ~400B archs on a single pod
    opt_cfg = opt_cfg or OptConfig(moments_dtype="bfloat16")
    out: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
    }
    with partition.use_mesh(
        mesh, overrides=layout_overrides(cfg, spec.global_batch, mesh)
    ):
        param_shapes = model.init_shapes()
        if spec.kind != "train":
            # serving runs from bf16 weights (training keeps f32 masters)
            param_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape,
                    jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype,
                ),
                param_shapes,
            )
        p_sh = param_shardings(mesh, param_shapes)
        inputs = model.input_specs(spec)
        if spec.kind == "train":
            opt_shapes = jax.eval_shape(lambda: init_state(param_shapes, opt_cfg))
            o_sh = opt_state_shardings(mesh, opt_shapes)
            b_sh = batch_shardings(mesh, inputs)
            # auto-escalate gradient accumulation until the cell fits HBM
            for micro in (1, 2, 4, 8):
                step = make_train_step(model, opt_cfg, microbatches=micro)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(param_shapes, opt_shapes, inputs)
                compiled_try = lowered.compile()
                peak = hlo_analysis.memory_summary(compiled_try)[
                    "peak_per_device_gib"
                ]
                if peak <= 15.0 or micro == 8:
                    out["microbatches"] = micro
                    break
        elif spec.kind == "prefill":
            b_sh = batch_shardings(mesh, inputs)
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(param_shapes, inputs)
        else:  # decode
            c_sh = cache_shardings(mesh, inputs["cache"])
            t_sh = batch_shardings(mesh, inputs["token"])
            step = make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(param_shapes, inputs["cache"], inputs["token"])
        t_lower = time.time() - t0
        compiled = compiled_try if spec.kind == "train" else lowered.compile()
        t_compile = time.time() - t0 - t_lower

    flops, byts = hlo_analysis.flops_bytes(compiled)
    coll = hlo_analysis.collective_stats(compiled.as_text())
    mem = hlo_analysis.memory_summary(compiled)
    n_params = sum(
        functools.reduce(lambda a, b: a * b, x.shape, 1)
        for x in jax.tree.leaves(param_shapes)
    )
    out.update(
        {
            "n_params": int(n_params),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": byts,
            "collectives": coll.summary(),
            "memory": mem,
        }
    )
    if verbose:
        print(
            f"[{out['mesh']}] {arch} × {shape_name}: OK "
            f"(compile {t_compile:.0f}s, peak {mem['peak_per_device_gib']:.2f} GiB/dev, "
            f"{coll.total_bytes/2**20:.1f} MiB collectives/dev/step-body)"
        )
    return out


# ---------------------------------------------------------------------------
# the full matrix
# ---------------------------------------------------------------------------


def run_all(
    archs=None, shapes=None, meshes=(False, True), out_path: Optional[str] = None
):
    archs = archs or list(configs.ARCH_IDS)
    shapes = shapes or [s.name for s in SHAPES]
    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shp in shapes:
                try:
                    results.append(dryrun_cell(arch, shp, multi_pod=multi_pod))
                except Exception as e:  # noqa: BLE001 — record, keep going
                    traceback.print_exc()
                    results.append(
                        {
                            "arch": arch, "shape": shp,
                            "mesh": "2x16x16" if multi_pod else "16x16",
                            "status": "error", "error": repr(e)[:500],
                        }
                    )
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run matrix: {n_ok} ok / {n_skip} skipped-by-design / {n_err} errors")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.all:
        meshes = (False,) if args.single_pod_only else (False, True)
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        run_all(archs=archs, shapes=shapes, meshes=meshes, out_path=args.out)
    else:
        res = dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
