"""DBFlex-JAX: fine-tuned data structures for analytical query processing,
re-derived for TPU pods.  See DESIGN.md."""

__version__ = "1.0.0"
