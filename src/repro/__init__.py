"""DBFlex-JAX: fine-tuned data structures for analytical query processing,
re-derived for TPU pods.  See DESIGN.md.

The public entry point is :func:`repro.connect`::

    import repro
    session = repro.connect(db, memory_budget=..., shards=..., adapt=...)
    result = session.query("q18", threshold=200)
    print(session.report().summary())
"""

__version__ = "1.1.0"

__all__ = ["connect", "Session"]


def __getattr__(name):
    # lazy: importing `repro` must stay light (the session pulls in jax)
    if name in ("connect", "Session"):
        from repro import session as _session

        return getattr(_session, {"connect": "connect", "Session": "Session"}[name])
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
