"""Out-of-core compressed columnar storage (DESIGN.md §10).

A ``ChunkedTable`` keeps a relation host-resident as fixed-size row chunks
whose columns are individually compressed with one of four chunk encodings —
dictionary, run-length, bit-packing, frame-of-reference — chosen per column
(per chunk) by the storage cost model: minimize host→device transfer plus
in-register decode per pass (``core.cost.StorageCostModel``).  Decode is
**exact**: every encoding round-trips int32/float32 columns bitwise, so the
streamed execution paths (``exec.engine`` XLA per-chunk decode, the fused
Pallas kernel's in-register tile decode) are bit-identical to running over
the uncompressed arrays.

Representation invariants (shared with ``kernels.fused_pipeline``):

* every encoded payload is **tile-aligned** to ``block`` rows (the kernel's
  ``ROW_BLOCK``): bit-packed words never straddle a tile, RLE run tables are
  per-tile, so a kernel grid step can decode its tile from a fixed-size
  slice without cross-tile state;
* bit widths are powers of two ≤ 16 (1/2/4/8/16) so values never straddle a
  32-bit word — unpack is one vectorized shift+mask;
* pad rows (beyond ``n``) decode to the column's first value — they are
  masked dead by the chunk's live mask, never observed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost as C
from repro.core.cardinality import RelStats
from repro.data.table import Table, from_numpy, table_stats

#: tile size every encoded payload is aligned to (== fused kernel ROW_BLOCK)
BLOCK = 1024

#: default rows per chunk (multiple of BLOCK; 64Ki rows ≈ 256 KiB/column)
CHUNK_ROWS = 1 << 16

_POW2_BITS = (1, 2, 4, 8, 16)


def _width_for(span: int) -> Optional[int]:
    """Smallest power-of-two bit width (≤16) representing [0, span]."""
    if span < 0:
        return None
    bits = max(1, int(span).bit_length())
    for w in _POW2_BITS:
        if bits <= w:
            return w
    return None


def _n_tiles(n: int, block: int) -> int:
    return max(1, -(-n // block))


# ---------------------------------------------------------------------------
# bit packing: values < 2**bits into int32 words, vpw = 32 // bits per word
# ---------------------------------------------------------------------------


def pack_bits(vals: np.ndarray, bits: int, block: int = BLOCK) -> np.ndarray:
    """Pack non-negative ints < 2**bits into int32 words, tile-aligned.

    Input is padded to a multiple of ``block`` with zeros; output is one
    int32 word stream of ``n_tiles * block // (32 // bits)`` words — each
    tile owns a fixed, whole-word slice.
    """
    assert bits in _POW2_BITS, bits
    vpw = 32 // bits
    n = len(vals)
    npad = _n_tiles(n, block) * block
    v = np.zeros((npad,), np.uint32)
    v[:n] = vals.astype(np.int64).astype(np.uint32)
    v = v.reshape(-1, vpw)
    shifts = (np.arange(vpw, dtype=np.uint32) * np.uint32(bits))
    words = np.bitwise_or.reduce(v << shifts, axis=1)
    return words.astype(np.uint32).view(np.int32)


def unpack_bits(words: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of ``pack_bits`` — returns int32 values in [0, 2**bits)."""
    vpw = 32 // bits
    w = np.asarray(words).view(np.uint32)
    shifts = (np.arange(vpw, dtype=np.uint32) * np.uint32(bits))
    mask = np.uint32((1 << bits) - 1) if bits < 32 else np.uint32(0xFFFFFFFF)
    vals = ((w[:, None] >> shifts) & mask).reshape(-1)
    return vals[:n].astype(np.int32)


# ---------------------------------------------------------------------------
# one encoded column chunk
# ---------------------------------------------------------------------------


@dataclass
class EncodedColumn:
    """One column of one chunk under one encoding.

    kinds / payloads:
      ``plain``    {"data": dtype[n]}
      ``bitpack``  {"words": int32[nt*W]}          meta: bits (ref == 0)
      ``for``      {"words": int32[nt*W]}          meta: bits, ref (frame lo)
      ``dict``     {"words": int32[nt*W], "values": dtype[d]}  meta: bits, d
      ``rle``      {"values": dtype[nt, R], "ends": int32[nt, R]}  meta: runs
    """

    kind: str
    dtype: str  # decoded dtype name: "int32" | "float32"
    n: int
    block: int
    payload: Dict[str, np.ndarray]
    meta: Dict[str, int] = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.payload.values()))

    @property
    def decoded_nbytes(self) -> int:
        return 4 * self.n

    def decode(self) -> np.ndarray:
        """Exact reconstruction of the original column values."""
        if self.kind == "plain":
            return self.payload["data"]
        if self.kind in ("bitpack", "for"):
            vals = unpack_bits(self.payload["words"], self.meta["bits"], self.n)
            ref = self.meta.get("ref", 0)
            if ref:
                vals = (vals.astype(np.int64) + ref).astype(np.int32)
            return vals
        if self.kind == "dict":
            codes = unpack_bits(self.payload["words"], self.meta["bits"], self.n)
            return self.payload["values"][codes]
        if self.kind == "rle":
            values, ends = self.payload["values"], self.payload["ends"]
            lengths = np.diff(ends, axis=1, prepend=0)
            out = np.concatenate(
                [np.repeat(values[t], lengths[t]) for t in range(len(values))]
            )
            return out[: self.n]
        raise ValueError(f"unknown encoding {self.kind!r}")


def _rle_tile_tables(
    a: np.ndarray, block: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-tile RLE run tables: (values [nt, R], ends [nt, R], R).

    ``ends`` are cumulative within-tile end offsets (strictly increasing to
    ``block``); rows are padded by repeating the final (value, block) entry,
    i.e. zero-length runs, so decode is padding-oblivious.
    """
    n = len(a)
    nt = _n_tiles(n, block)
    npad = nt * block
    ap = np.concatenate([a, np.repeat(a[-1:], npad - n)]) if npad > n else a
    change = np.nonzero(ap[1:] != ap[:-1])[0] + 1
    starts = np.union1d(
        np.concatenate([[0], change]), np.arange(0, npad, block)
    ).astype(np.int64)
    tile_of = starts // block
    counts = np.bincount(tile_of, minlength=nt)
    R = int(counts.max())
    values = np.empty((nt, R), ap.dtype)
    ends = np.empty((nt, R), np.int32)
    bounds = np.append(starts, npad)
    pos = 0
    for t in range(nt):
        k = counts[t]
        sl = slice(pos, pos + k)
        values[t, :k] = ap[starts[sl]]
        ends[t, :k] = bounds[pos + 1 : pos + 1 + k] - t * block
        values[t, k:] = values[t, k - 1]
        ends[t, k:] = block
        pos += k
    return values, ends, R


def encode_column(
    a: np.ndarray,
    block: int = BLOCK,
    model: Optional[C.StorageCostModel] = None,
    mode: str = "auto",
) -> EncodedColumn:
    """Encode one column chunk, choosing the cheapest encoding under the
    storage cost model (``mode="auto"``) or forcing a specific kind."""
    a = np.asarray(a)
    assert a.ndim == 1 and a.dtype in (np.int32, np.float32), (a.dtype, a.shape)
    n = len(a)
    is_float = a.dtype == np.float32
    model = model or C.StorageCostModel()

    candidates: Dict[str, Tuple[int, Dict[str, np.ndarray], Dict[str, int]]] = {}
    candidates["plain"] = (a.nbytes, {"data": a}, {})
    nt = _n_tiles(n, block)

    # run-length: per-tile tables (exact tile-form bytes, padding included)
    if n:
        changes = int(np.count_nonzero(a[1:] != a[:-1])) + 1
        est_rle = (changes + nt) * 8.0  # runs + one boundary split per tile
        if mode == "rle" or (mode == "auto" and est_rle < a.nbytes):
            values, ends, R = _rle_tile_tables(a, block)
            candidates["rle"] = (
                values.nbytes + ends.nbytes,
                {"values": values, "ends": ends},
                {"runs": R},
            )

    def _packed_nbytes(bits: int) -> int:
        return nt * (block // (32 // bits)) * 4

    if not is_float and n:
        lo, hi = int(a.min()), int(a.max())
        w = _width_for(hi) if lo >= 0 else None
        if w is not None:
            candidates["bitpack"] = (
                _packed_nbytes(w),
                {},  # packed lazily below if chosen
                {"bits": w, "ref": 0},
            )
        wf = _width_for(int(hi) - int(lo))
        if wf is not None and lo != 0:
            candidates["for"] = (
                _packed_nbytes(wf) + 4,
                {},
                {"bits": wf, "ref": lo},
            )

    if n:
        values = np.unique(a)
        d = len(values)
        wd = _width_for(d - 1)
        if wd is not None:
            candidates["dict"] = (
                values.nbytes + _packed_nbytes(wd),
                {"values": values},
                {"bits": wd, "d": d},
            )

    if mode != "auto":
        if mode not in candidates:
            raise ValueError(f"encoding {mode!r} inapplicable to this column")
        kind = mode
    else:
        kind, best_s = "plain", model.encoding_seconds("plain", a.nbytes, n)
        for k, (nbytes, _, _) in candidates.items():
            if k == "plain" or nbytes >= a.nbytes:
                continue
            s = model.encoding_seconds(k, nbytes, n)
            if s < best_s:
                kind, best_s = k, s

    nbytes, payload, meta = candidates[kind]
    if kind in ("bitpack", "for"):
        base = a if kind == "bitpack" else (a - np.int32(meta["ref"]))
        payload = {"words": pack_bits(base, meta["bits"], block)}
    elif kind == "dict":
        codes = np.searchsorted(payload["values"], a).astype(np.int32)
        payload = {"values": payload["values"], "words": pack_bits(codes, meta["bits"], block)}
    return EncodedColumn(kind, str(a.dtype), n, block, payload, dict(meta))


# ---------------------------------------------------------------------------
# chunked host-resident tables
# ---------------------------------------------------------------------------


@dataclass
class ChunkedTable:
    """A relation stored host-side as per-chunk encoded columns.

    Presents the ``Table`` metadata surface the planner and executor read
    (``nrows``, ``sorted_on``, ``names``, Σ stats) without materializing any
    decoded column; ``chunk(i)`` decodes one chunk (optionally padded to
    ``chunk_rows`` with a dead-row mask so every chunk shares one static
    shape), ``decode()`` materializes the whole relation (tests / fallback).
    """

    chunks: List[Dict[str, EncodedColumn]]
    chunk_rows: int
    nrows: int
    schema: Dict[str, str]  # column -> decoded dtype name
    sorted_on: Tuple[str, ...] = ()
    stats: Optional[RelStats] = None
    mask: None = None  # interface parity with Table (always all-live)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def names(self) -> Tuple[str, ...]:
        return tuple(self.schema)

    @property
    def columns(self) -> Dict[str, str]:
        # schema-shaped stand-in: consumers needing arrays must decode
        return self.schema

    def chunk_nrows(self, i: int) -> int:
        return next(iter(self.chunks[i].values())).n

    def chunk(
        self, i: int, cols: Optional[Sequence[str]] = None, pad: bool = False
    ) -> Table:
        """Decode chunk ``i`` (only ``cols`` if given) into a ``Table``.
        ``pad=True`` pads the final short chunk to ``chunk_rows`` with the
        last row repeated and a live mask marking the tail dead — every
        chunk then has one static shape (one compiled region fn)."""
        enc = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(enc)
        out: Dict[str, np.ndarray] = {c: enc[c].decode() for c in names}
        n = self.chunk_nrows(i)
        mask = None
        if pad and n < self.chunk_rows:
            tail = self.chunk_rows - n
            out = {c: np.concatenate([a, np.repeat(a[-1:], tail)]) for c, a in out.items()}
            mask = np.zeros((self.chunk_rows,), bool)
            mask[:n] = True
            n = self.chunk_rows
        import jax.numpy as jnp

        t = Table(
            {c: jnp.asarray(a) for c, a in out.items()},
            n,
            mask=None if mask is None else jnp.asarray(mask),
            sorted_on=self.sorted_on,
        )
        return t

    def decode(self, cols: Optional[Sequence[str]] = None) -> Table:
        names = tuple(cols) if cols is not None else tuple(self.schema)
        parts = {
            c: np.concatenate([ch[c].decode() for ch in self.chunks])
            for c in names
        }
        import jax.numpy as jnp

        return Table(
            {c: jnp.asarray(a) for c, a in parts.items()},
            self.nrows,
            sorted_on=self.sorted_on,
        )

    @property
    def encoded_nbytes(self) -> int:
        return sum(e.nbytes for ch in self.chunks for e in ch.values())

    @property
    def decoded_nbytes(self) -> int:
        return 4 * self.nrows * len(self.schema)

    def encodings(self) -> Dict[str, Tuple[str, ...]]:
        """Per-column tuple of chunk encodings (diagnostics / signatures)."""
        return {
            c: tuple(ch[c].kind for ch in self.chunks) for c in self.schema
        }

    def signature(self) -> tuple:
        return (
            self.nrows,
            self.chunk_rows,
            self.sorted_on,
            tuple(sorted(self.schema.items())),
        )

    # -- device streaming -------------------------------------------------

    def chunk_decode_spec(self, i: int, cols: Optional[Sequence[str]] = None):
        """Static decode recipe for chunk ``i`` — everything a jitted
        region fn needs to trace the on-device decode of the uploaded
        payload: ``(n, ((col, kind, bits, ref, block), ...))``.  Hashable;
        part of the region-fn cache key (full chunks of a uniformly
        encoded column share one spec, so one compile serves them all)."""
        from repro.testing import faults as _faults

        _faults.check("chunk-decode", detail=f"chunk {i}")
        enc = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(enc)
        return (
            self.chunk_nrows(i),
            tuple(
                (c, e.kind, e.meta.get("bits", 0), e.meta.get("ref", 0),
                 e.block)
                for c in names
                for e in (enc[c],)
            ),
        )

    def upload_chunk(self, i: int, cols: Optional[Sequence[str]] = None):
        """Start the host→device transfer of chunk ``i``'s **encoded**
        payloads.  ``jax.device_put`` dispatches asynchronously, so calling
        this for chunk ``i+1`` before computing on chunk ``i`` overlaps the
        next transfer with the current chunk's compute.  Returns
        ``(payloads, h2d_bytes)`` where payloads is ``{col: {name: array}}``
        — only encoded bytes cross the link."""
        import jax

        from repro.testing import faults as _faults

        _faults.check("h2d", detail=f"chunk {i}")
        enc = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(enc)
        nbytes = sum(enc[c].nbytes for c in names)
        up = {
            c: {k: jax.device_put(v) for k, v in enc[c].payload.items()}
            for c in names
        }
        return up, nbytes

    def chunk_device(
        self,
        i: int,
        cols: Optional[Sequence[str]] = None,
        pad: bool = False,
        uploaded=None,
    ) -> Table:
        """Chunk ``i`` as a device ``Table``, decoded ON DEVICE from the
        uploaded encoded payload (``kernels.decode.decode_device`` —
        bitwise equal to host ``decode()``).  ``pad=True`` gives every
        chunk the same static shape (``chunk_rows``) AND an explicit live
        mask (all-ones when full) so one compiled region fn serves all
        chunks."""
        import jax.numpy as jnp

        from ..kernels import decode as DK

        enc = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(enc)
        if uploaded is None:
            uploaded, _ = self.upload_chunk(i, names)
        out = {c: DK.decode_device(enc[c], uploaded[c]) for c in names}
        n = self.chunk_nrows(i)
        mask = None
        if pad:
            if n < self.chunk_rows:
                tail = self.chunk_rows - n
                out = {
                    c: jnp.concatenate([a, jnp.repeat(a[-1:], tail)])
                    for c, a in out.items()
                }
            mask = jnp.arange(self.chunk_rows, dtype=jnp.int32) < n
            n = self.chunk_rows
        return Table(out, n, mask=mask, sorted_on=self.sorted_on)


@dataclass
class HostChunkedTable:
    """A *decoded* host-resident chunked relation — the spill target for
    streamed Project-terminal regions (e.g. the lineitem-sized revenue
    intermediates of q5/q9).  Chunks are plain numpy arrays padded to
    ``chunk_rows`` with an explicit per-chunk live mask; downstream
    pipelines stream it through the same chunk-at-a-time machinery as
    ``ChunkedTable`` (duck-typed: same metadata surface and
    ``upload_chunk``/``chunk_device`` protocol)."""

    chunks: List[Dict[str, np.ndarray]]
    masks: List[np.ndarray]  # [chunk_rows] bool, live rows per chunk
    chunk_rows: int
    nrows: int  # logical (source) row count
    schema: Dict[str, str]
    sorted_on: Tuple[str, ...] = ()
    stats: Optional[RelStats] = None
    mask: None = None  # interface parity with Table

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def names(self) -> Tuple[str, ...]:
        return tuple(self.schema)

    @property
    def columns(self) -> Dict[str, str]:
        return self.schema

    def chunk_nrows(self, i: int) -> int:
        return int(self.masks[i].sum())

    @property
    def encoded_nbytes(self) -> int:  # stored decoded: raw bytes
        return sum(
            a.nbytes for ch in self.chunks for a in ch.values()
        ) + sum(m.nbytes for m in self.masks)

    @property
    def decoded_nbytes(self) -> int:
        return 4 * self.n_chunks * self.chunk_rows * len(self.schema)

    def chunk_decode_spec(self, i: int, cols: Optional[Sequence[str]] = None):
        """Spill chunks are stored decoded+padded; the region fn reads the
        uploaded arrays verbatim and the live mask from the payload."""
        from repro.testing import faults as _faults

        _faults.check("chunk-decode", detail=f"spill chunk {i}")
        ch = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(ch)
        return (self.chunk_rows, tuple((c, "raw", 0, 0, 0) for c in names))

    def upload_chunk(self, i: int, cols: Optional[Sequence[str]] = None):
        import jax

        from repro.testing import faults as _faults

        _faults.check("h2d", detail=f"spill chunk {i}")
        ch = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(ch)
        nbytes = sum(ch[c].nbytes for c in names) + self.masks[i].nbytes
        up = {c: {"data": jax.device_put(ch[c])} for c in names}
        up["__mask__"] = {"data": jax.device_put(self.masks[i])}
        return up, nbytes

    def chunk_device(
        self,
        i: int,
        cols: Optional[Sequence[str]] = None,
        pad: bool = True,
        uploaded=None,
    ) -> Table:
        ch = self.chunks[i]
        names = tuple(cols) if cols is not None else tuple(ch)
        if uploaded is None:
            uploaded, _ = self.upload_chunk(i, names)
        return Table(
            {c: uploaded[c]["data"] for c in names},
            self.chunk_rows,
            mask=uploaded["__mask__"]["data"],
            sorted_on=self.sorted_on,
        )

    def chunk(
        self, i: int, cols: Optional[Sequence[str]] = None, pad: bool = True
    ) -> Table:
        return self.chunk_device(i, cols, pad)

    def decode(self, cols: Optional[Sequence[str]] = None) -> Table:
        import jax.numpy as jnp

        # structural pad rows only ever occupy the final chunk's tail (the
        # source invariant: every chunk but the last is full), so trimming
        # to ``nrows`` reproduces the resident table's exact shape — row
        # count changes reduction tree shapes, so this matters for bitwise
        # parity of downstream consumers, not just for economy
        names = tuple(cols) if cols is not None else tuple(self.schema)
        parts = {
            c: np.concatenate([ch[c] for ch in self.chunks])[: self.nrows]
            for c in names
        }
        mask = np.concatenate(self.masks)[: self.nrows]
        return Table(
            {c: jnp.asarray(a) for c, a in parts.items()},
            self.nrows,
            mask=jnp.asarray(mask),
            sorted_on=self.sorted_on,
        )


def is_chunked(x) -> bool:
    """True for host-resident chunked relations (either encoded fact
    storage or decoded spill intermediates) that must be streamed."""
    return isinstance(x, (ChunkedTable, HostChunkedTable))


def chunk_table(
    t: Table,
    chunk_rows: int = CHUNK_ROWS,
    block: int = BLOCK,
    model: Optional[C.StorageCostModel] = None,
) -> ChunkedTable:
    """Encode a fully-materialized ``Table`` into a host-resident
    ``ChunkedTable`` — per-chunk, per-column encoding choice, exact Σ stats
    captured once from the decoded data."""
    assert t.mask is None, "cannot chunk a masked table"
    assert chunk_rows % block == 0, (chunk_rows, block)
    cols = {c: np.asarray(a) for c, a in t.columns.items()}
    stats = table_stats(t)
    chunks: List[Dict[str, EncodedColumn]] = []
    for start in range(0, max(t.nrows, 1), chunk_rows):
        stop = min(start + chunk_rows, t.nrows)
        chunks.append(
            {
                c: encode_column(a[start:stop], block, model)
                for c, a in cols.items()
            }
        )
    schema = {c: str(a.dtype) for c, a in cols.items()}
    return ChunkedTable(
        chunks, chunk_rows, t.nrows, schema, tuple(t.sorted_on), stats
    )


def chunk_db(
    db: Dict[str, Table],
    memory_budget_bytes: Optional[int] = None,
    chunk_rows: int = CHUNK_ROWS,
    block: int = BLOCK,
    model: Optional[C.StorageCostModel] = None,
) -> Dict[str, object]:
    """Apply the storage plan to a database dict: relations the budget
    cannot keep decoded-resident become ``ChunkedTable``s (largest first),
    the rest stay as-is.  With no budget every relation stays resident —
    the out-of-core layer is strictly opt-in."""
    if memory_budget_bytes is None:
        return dict(db)
    from repro.data.table import collect_stats

    sigma = collect_stats(db)
    decisions = C.storage_plan(
        sigma, memory_budget_bytes, model, block=block, chunk_rows=chunk_rows
    )
    out: Dict[str, object] = {}
    for rel, t in db.items():
        if decisions[rel].mode == "streamed":
            out[rel] = chunk_table(t, chunk_rows, block, model)
        else:
            out[rel] = t
    return out
