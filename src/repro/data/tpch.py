"""Synthetic TPC-H-shaped data generator.

Generates the five relations used by the paper's evaluation queries
(Q1, Q3, Q5, Q9, Q18) with TPC-H-faithful structure at configurable scale:
key/foreign-key joins, compound lineitem keys ordered on (orderkey), and
value distributions that make selectivities meaningful.  All integers are
kept dense so compound keys pack exactly (``data.table.pack_keys``).

This is a *generator*, not the official dbgen: the paper's claims we test
(dictionary-choice crossovers, mixed-implementation wins) depend on the
relational shape and cardinality ratios, which we preserve: ~4:1
lineitem:orders, 10:1 orders:customer, parts/suppliers scaled alongside.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from .table import Table, from_numpy


@dataclass
class TPCH:
    lineitem: Table
    orders: Table
    customer: Table
    part: Table
    supplier: Table
    nation: Table

    def tables(self) -> Dict[str, Table]:
        return {
            "lineitem": self.lineitem,
            "orders": self.orders,
            "customer": self.customer,
            "part": self.part,
            "supplier": self.supplier,
            "nation": self.nation,
        }


def generate(scale: float = 0.01, seed: int = 0) -> TPCH:
    """scale=1.0 ≈ 6M lineitems (TPC-H SF1); default 0.01 → 60k (CI-sized)."""
    rng = np.random.default_rng(seed)
    n_li = int(6_000_000 * scale)
    n_ord = int(1_500_000 * scale)
    n_cust = int(150_000 * scale)
    n_part = max(int(200_000 * scale), 64)
    n_supp = max(int(10_000 * scale), 16)
    n_nation = 25

    # --- orders: O_ORDERKEY dense [0, n_ord); dates uniform in [0,1)
    o_custkey = rng.integers(0, n_cust, n_ord).astype(np.int32)
    o_orderdate = rng.random(n_ord).astype(np.float32)
    orders = from_numpy(
        {
            "orderkey": np.arange(n_ord, dtype=np.int32),
            "custkey": o_custkey,
            "orderdate": o_orderdate,
            "shippriority": rng.integers(0, 5, n_ord).astype(np.int32),
            "totalprice": (rng.random(n_ord) * 1e4).astype(np.float32),
        },
        sorted_on=("orderkey",),
    )

    # --- lineitem: ~4 rows per order, physically ordered by orderkey
    li_order = np.sort(rng.integers(0, n_ord, n_li)).astype(np.int32)
    lineitem = from_numpy(
        {
            "orderkey": li_order,
            "partkey": rng.integers(0, n_part, n_li).astype(np.int32),
            "suppkey": rng.integers(0, n_supp, n_li).astype(np.int32),
            "quantity": rng.integers(1, 51, n_li).astype(np.float32),
            "extendedprice": (rng.random(n_li) * 1e3 + 1).astype(np.float32),
            "discount": (rng.random(n_li) * 0.1).astype(np.float32),
            "tax": (rng.random(n_li) * 0.08).astype(np.float32),
            "returnflag": rng.integers(0, 3, n_li).astype(np.int32),
            "linestatus": rng.integers(0, 2, n_li).astype(np.int32),
            "shipdate": rng.random(n_li).astype(np.float32),
        },
        sorted_on=("orderkey",),
    )

    customer = from_numpy(
        {
            "custkey": np.arange(n_cust, dtype=np.int32),
            "nationkey": rng.integers(0, n_nation, n_cust).astype(np.int32),
            "mktsegment": rng.integers(0, 5, n_cust).astype(np.int32),
            "acctbal": (rng.random(n_cust) * 1e4).astype(np.float32),
        },
        sorted_on=("custkey",),
    )

    part = from_numpy(
        {
            "partkey": np.arange(n_part, dtype=np.int32),
            "brand": rng.integers(0, 25, n_part).astype(np.int32),
            "color": rng.integers(0, 92, n_part).astype(np.int32),  # p_name LIKE
            "retailprice": (rng.random(n_part) * 2e3).astype(np.float32),
        },
        sorted_on=("partkey",),
    )

    supplier = from_numpy(
        {
            "suppkey": np.arange(n_supp, dtype=np.int32),
            "nationkey": rng.integers(0, n_nation, n_supp).astype(np.int32),
        },
        sorted_on=("suppkey",),
    )

    nation = from_numpy(
        {
            "nationkey": np.arange(n_nation, dtype=np.int32),
            "regionkey": (np.arange(n_nation, dtype=np.int32) % 5),
        },
        sorted_on=("nationkey",),
    )

    return TPCH(lineitem, orders, customer, part, supplier, nation)


def generate_chunked(
    scale: float = 0.22,
    seed: int = 0,
    memory_budget_bytes: int = 16 << 20,
    chunk_rows: int = 1 << 16,
) -> Dict[str, object]:
    """Generate at ``scale`` and apply the out-of-core storage plan: fact
    relations the device ``memory_budget_bytes`` cannot hold decoded become
    host-resident compressed ``ChunkedTable``s the engine streams chunk-by-
    chunk (DESIGN.md §10); small dimensions stay device-resident.  This is
    the large-scale entry point — decoded device residency stops being
    assumed at exactly the point the budget says it must."""
    from .storage import chunk_db

    return chunk_db(
        generate(scale, seed).tables(),
        memory_budget_bytes=memory_budget_bytes,
        chunk_rows=chunk_rows,
    )
