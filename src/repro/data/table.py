"""Column-store tables + statistics collection.

A ``Table`` is a dict of equal-length jnp columns plus an optional selection
mask (static-shape filtering: rows are never compacted, only masked — the
vectorized-engine discipline).  String columns are dictionary-encoded to
int32 at load time.  ``collect_stats`` builds the Σ statistics the cost
model consumes (row counts, per-column distinct/min/max, physical sort
order) from the actual data — exact stats, so cost-model experiments isolate
Δ quality from cardinality-estimation error, like the paper's setup.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cardinality import CardModel, ColumnStats, RelStats
from repro.dicts import base as dbase


@dataclass
class Table:
    columns: Dict[str, jax.Array]
    nrows: int
    mask: Optional[jax.Array] = None  # bool [nrows]; None = all live
    sorted_on: Tuple[str, ...] = ()

    def col(self, name: str) -> jax.Array:
        return self.columns[name]

    def names(self) -> Tuple[str, ...]:
        return tuple(self.columns)

    def live_mask(self) -> jax.Array:
        if self.mask is None:
            return jnp.ones((self.nrows,), bool)
        return self.mask

    def with_mask(self, mask: jax.Array) -> "Table":
        new = mask if self.mask is None else (self.mask & mask)
        return replace(self, mask=new)

    def multiplicity(self) -> jax.Array:
        """Bag multiplicity column (1.0 for live rows, 0.0 for masked)."""
        return self.live_mask().astype(jnp.float32)


def from_numpy(cols: Dict[str, np.ndarray], sorted_on: Sequence[str] = ()) -> Table:
    n = len(next(iter(cols.values())))
    out = {}
    for k, v in cols.items():
        v = np.asarray(v)
        if v.dtype.kind in "iu":
            out[k] = jnp.asarray(v.astype(np.int32))
        elif v.dtype.kind == "f":
            out[k] = jnp.asarray(v.astype(np.float32))
        elif v.dtype.kind in "US O":  # strings -> dictionary-encode
            _, codes = np.unique(v, return_inverse=True)
            out[k] = jnp.asarray(codes.astype(np.int32))
        else:  # pragma: no cover
            raise TypeError(f"unsupported column dtype {v.dtype} for {k}")
        assert len(v) == n, f"ragged column {k}"
    return Table(out, n, sorted_on=tuple(sorted_on))


# ---------------------------------------------------------------------------
# key packing: compound keys -> single int32
# ---------------------------------------------------------------------------


def pack_keys(table: Table, cols: Sequence[str], domains: Optional[Dict[str, int]] = None) -> jax.Array:
    """Pack the named columns into one int32 key.  Uses exact arithmetic
    packing when the product of domains fits 31 bits (collision-free),
    otherwise falls back to hash mixing (collision probability ~ n²/2³¹ —
    acceptable for grouping, documented for joins)."""
    if len(cols) == 1:
        return table.col(cols[0]).astype(jnp.int32)
    doms = []
    for c in cols:
        d = (domains or {}).get(c)
        if d is None:
            d = int(np.asarray(jnp.max(table.col(c)))) + 1
        doms.append(max(d, 1))
    total = 1
    for d in doms:
        total *= d
    if total < 2**31:
        key = jnp.zeros((table.nrows,), jnp.int32)
        for c, d in zip(cols, doms):
            key = key * jnp.int32(d) + table.col(c).astype(jnp.int32)
        return key
    # hash mixing fallback
    key = jnp.zeros((table.nrows,), jnp.uint32)
    for c in cols:
        key = dbase._mix(key.astype(jnp.int32) ^ table.col(c).astype(jnp.int32), dbase._H1)
    return (key & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Σ statistics from real data
# ---------------------------------------------------------------------------


def table_stats(t) -> RelStats:
    if hasattr(t, "stats") and t.stats is not None:  # ChunkedTable: exact
        return t.stats  # stats captured once at encode time (storage.py)
    cols = {}
    for name, arr in t.columns.items():
        a = np.asarray(arr)
        if t.mask is not None:
            a = a[np.asarray(t.mask)]
        if len(a) == 0:
            cols[name] = ColumnStats(distinct=0, lo=0.0, hi=0.0)
            continue
        cols[name] = ColumnStats(
            distinct=float(len(np.unique(a))),
            lo=float(a.min()),
            hi=float(a.max()),
        )
    rows = float(t.nrows if t.mask is None else int(np.asarray(t.mask).sum()))
    return RelStats(rows=rows, columns=cols, sorted_on=t.sorted_on)


def collect_stats(tables: Dict[str, Table]) -> CardModel:
    """Σ from the actual data.  Accepts a mixed db of ``Table`` and
    host-resident ``storage.ChunkedTable`` values — chunked relations carry
    their exact stats from encode time, so Σ (and the capacities/choices
    derived from it) is identical to the fully-decoded database's."""
    return CardModel({name: table_stats(t) for name, t in tables.items()})
