from .profiler import ProfileRow, ProfileTable, profile, profile_quick  # noqa: F401
from .regression import MODEL_ZOO, make, with_log_features  # noqa: F401
from .store import (  # noqa: F401
    AllInOneCostModel,
    LearnedCostModel,
    install,
    load_model,
    load_profile,
    save_model,
    train,
    train_all_in_one,
)
