"""Installation-stage profiler (paper §4.1 / Fig. 3 "Installation Stage").

Generates the synthetic profiling workload, times every registered
dictionary backend's operations **on the current machine**, and returns a
training table:

    features: dictionary size, number of accessed tuples, orderedness
    label   : wall seconds for the whole operation batch

ops: ``insert`` (build of n elements), ``lookup_hit`` (n present keys),
``lookup_miss`` (n absent keys); each × ordered/unordered key sequences.
Hash backends are profiled under both orderings too — the paper notes their
order-insensitivity, and the learned model should *discover* that, not
assume it.

Timing protocol: jit-compiled op, one warm-up call (compile), then the
median of ``repeats`` timed calls with ``block_until_ready``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dicts import base as dbase
from repro.dicts import registry

DEFAULT_SIZES = (2**4, 2**6) + tuple(2**p for p in range(8, 18))  # 16 .. 128k
QUICK_SIZES = (2**8, 2**11, 2**14)
OPS = ("insert", "lookup_hit", "lookup_miss")


@dataclass
class ProfileRow:
    ds: str
    op: str
    ordered: bool
    size: int  # dictionary cardinality
    n: int  # accessed/inserted tuples
    seconds: float  # total batch seconds

    @property
    def per_op_ns(self) -> float:
        return self.seconds / max(self.n, 1) * 1e9


@dataclass
class ProfileTable:
    rows: List[ProfileRow] = field(default_factory=list)

    def filter(self, ds=None, op=None, ordered=None) -> "ProfileTable":
        out = [
            r
            for r in self.rows
            if (ds is None or r.ds == ds)
            and (op is None or r.op == op)
            and (ordered is None or r.ordered == ordered)
        ]
        return ProfileTable(out)

    def features_labels(self) -> Tuple[np.ndarray, np.ndarray]:
        X = np.array([[r.size, r.n] for r in self.rows], float)
        y = np.array([r.seconds for r in self.rows], float)
        return X, y

    def onehot_features_labels(self) -> Tuple[np.ndarray, np.ndarray]:
        """'All in One Model' featurization: size, n, ordered + one-hot
        (dictionary, op) — the paper's §6.2.1 first method."""
        ds_names = sorted({r.ds for r in self.rows})
        X = []
        for r in self.rows:
            row = [r.size, r.n, float(r.ordered)]
            row += [1.0 if r.ds == d else 0.0 for d in ds_names]
            row += [1.0 if r.op == o else 0.0 for o in OPS]
            X.append(row)
        y = np.array([r.seconds for r in self.rows], float)
        return np.array(X, float), y

    def save(self, path: str) -> None:
        arr = np.array(
            [
                (r.ds, r.op, int(r.ordered), r.size, r.n, r.seconds)
                for r in self.rows
            ],
            dtype=object,
        )
        np.save(path, arr, allow_pickle=True)

    @classmethod
    def load(cls, path: str) -> "ProfileTable":
        arr = np.load(path, allow_pickle=True)
        return cls(
            [
                ProfileRow(str(ds), str(op), bool(int(o)), int(s), int(n), float(sec))
                for ds, op, o, s, n, sec in arr
            ]
        )


# ---------------------------------------------------------------------------
# timing helpers
# ---------------------------------------------------------------------------


def _time_fn(fn, *args, repeats: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warm-up + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _capacity_for(ds: str, size: int) -> int:
    cap = dbase.next_pow2(max(2 * size, 256))
    return cap


# ---------------------------------------------------------------------------
# the profiling sweep
# ---------------------------------------------------------------------------


def profile(
    backends: Optional[Sequence[str]] = None,
    sizes: Sequence[int] = DEFAULT_SIZES,
    lookup_ratios: Sequence[float] = (0.25, 1.0, 4.0),
    repeats: int = 3,
    seed: int = 0,
    verbose: bool = False,
) -> ProfileTable:
    backends = list(backends or registry.names())
    rng = np.random.default_rng(seed)
    table = ProfileTable()

    for size in sizes:
        cap = None
        # distinct int keys for the dictionary, plus disjoint miss keys
        universe = rng.choice(np.arange(1, 8 * size, dtype=np.int32), 2 * size, replace=False)
        present, absent = universe[:size], universe[size:]
        vals = rng.normal(size=(size, 1)).astype(np.float32)
        for ds in backends:
            mod = registry.get(ds)
            cap = _capacity_for(ds, size)
            for ordered in (False, True):
                ks = np.sort(present) if ordered else present
                vs = vals  # value order irrelevant for timing
                jks, jvs = jnp.asarray(ks), jnp.asarray(vs)

                # ---- insert: distinct batch AND duplicate-heavy batches
                # (bag aggregation: n_ops rows collapsing into `size` keys —
                # hash scatter conflicts degrade here, the model must see it)
                build = jax.jit(
                    lambda k, v, _m=mod, _c=cap, _o=ordered: _m.build(
                        k, v, _c, assume_sorted=_o
                    )
                )
                sec = _time_fn(build, jks, jvs, repeats=repeats)
                table.rows.append(
                    ProfileRow(ds, "insert", ordered, size, size, sec)
                )
                dups = (4, 16, 64) if size > 256 else (4, 16, 64, 1024, 8192)
                for dup in dups:
                    n_dup = min(size * dup, 2**18)
                    dk = rng.choice(present, n_dup, replace=True)
                    if ordered:
                        dk = np.sort(dk)
                    dv = rng.normal(size=(n_dup, 1)).astype(np.float32)
                    sec_d = _time_fn(
                        build, jnp.asarray(dk), jnp.asarray(dv), repeats=repeats
                    )
                    table.rows.append(
                        ProfileRow(ds, "insert", ordered, size, n_dup, sec_d)
                    )

                # ---- lookups against the built table
                t = build(jks, jvs)
                for ratio in lookup_ratios:
                    n = max(8, int(size * ratio))
                    hit_q = rng.choice(present, n, replace=True)
                    miss_q = rng.choice(absent, n, replace=True)
                    if ordered:
                        hit_q, miss_q = np.sort(hit_q), np.sort(miss_q)
                    lookup = jax.jit(lambda tt, q, _m=mod: _m.lookup(tt, q))
                    sec_hit = _time_fn(lookup, t, jnp.asarray(hit_q), repeats=repeats)
                    sec_miss = _time_fn(lookup, t, jnp.asarray(miss_q), repeats=repeats)
                    table.rows.append(
                        ProfileRow(ds, "lookup_hit", ordered, size, n, sec_hit)
                    )
                    table.rows.append(
                        ProfileRow(ds, "lookup_miss", ordered, size, n, sec_miss)
                    )
            if verbose:
                print(f"profiled {ds} size={size}")
    return table


def profile_quick(**kw) -> ProfileTable:
    kw.setdefault("sizes", QUICK_SIZES)
    kw.setdefault("lookup_ratios", (1.0,))
    kw.setdefault("repeats", 2)
    return profile(**kw)
