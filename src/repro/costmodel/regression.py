"""Regression model zoo — numpy re-implementations of the paper's §B models.

The paper trains scikit-learn regressors over the profiling set; sklearn is
not available offline here, so the same model classes are implemented from
scratch on numpy: Linear, Polynomial(2), KNN(k=4), DecisionTree(depth 5),
RandomForest(200), GradientBoost(200) and AdaBoost.R2(200).  All share a
tiny ``fit/predict`` interface and are serializable via ``to_state`` /
``from_state`` (plain dicts of ndarrays) for the installation-stage model
store.

Labels are fit in log-space (the paper's Figs. 9/16 evaluate proportionality
on a log scale, and §B explains why log features dominate); ``predict``
returns linear-space values.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# feature helpers
# ---------------------------------------------------------------------------


def with_log_features(X: np.ndarray) -> np.ndarray:
    """The paper's 'feature engineering': append log2 of each raw feature,
    plus the log-ratio of the first two (for dictionary ops: log(n/size) —
    the duplication factor that drives scatter-conflict degradation; see
    EXPERIMENTS.md §Perf engine-side iterations)."""
    logs = np.log2(np.maximum(X, 1.0))
    cols = [X, logs]
    if X.shape[1] >= 2:
        cols.append((logs[:, 1] - logs[:, 0])[:, None])
    return np.concatenate(cols, axis=1)


def _standardize_fit(X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    mu = X.mean(axis=0)
    sd = X.std(axis=0)
    sd = np.where(sd < 1e-12, 1.0, sd)
    return mu, sd


# ---------------------------------------------------------------------------
# base
# ---------------------------------------------------------------------------


class Regressor:
    name = "base"

    def fit(self, X: np.ndarray, y: np.ndarray) -> "Regressor":
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def to_state(self) -> Dict[str, Any]:
        raise NotImplementedError

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "Regressor":
        raise NotImplementedError


class _LogSpaceMixin:
    """Fit on log(y), predict exp — keeps the 3-orders-of-magnitude spread of
    dictionary op costs well-conditioned."""

    def _encode_y(self, y: np.ndarray) -> np.ndarray:
        return np.log(np.maximum(y, 1e-12))

    def _decode_y(self, z: np.ndarray) -> np.ndarray:
        return np.exp(z)


# ---------------------------------------------------------------------------
# linear / polynomial
# ---------------------------------------------------------------------------


class LinearRegression(Regressor, _LogSpaceMixin):
    name = "linear"

    def __init__(self) -> None:
        self.w: Optional[np.ndarray] = None
        self.mu = self.sd = None

    def _design(self, X: np.ndarray) -> np.ndarray:
        Z = (X - self.mu) / self.sd
        return np.concatenate([Z, np.ones((len(Z), 1))], axis=1)

    def fit(self, X, y):
        self.mu, self.sd = _standardize_fit(X)
        A = self._design(X)
        self.w, *_ = np.linalg.lstsq(A, self._encode_y(y), rcond=None)
        return self

    def predict(self, X):
        return self._decode_y(self._design(X) @ self.w)

    def to_state(self):
        return {"w": self.w, "mu": self.mu, "sd": self.sd}

    @classmethod
    def from_state(cls, s):
        m = cls()
        m.w, m.mu, m.sd = s["w"], s["mu"], s["sd"]
        return m


class PolynomialRegression(LinearRegression):
    name = "poly2"

    def _design(self, X):
        Z = (X - self.mu) / self.sd
        n, d = Z.shape
        cols = [Z, np.ones((n, 1))]
        for i in range(d):
            for j in range(i, d):
                cols.append((Z[:, i] * Z[:, j])[:, None])
        return np.concatenate(cols, axis=1)


# ---------------------------------------------------------------------------
# KNN (the paper's best: K=4 with log features)
# ---------------------------------------------------------------------------


class KNNRegressor(Regressor, _LogSpaceMixin):
    name = "knn4"

    def __init__(self, k: int = 4) -> None:
        self.k = k
        self.X: Optional[np.ndarray] = None
        self.z: Optional[np.ndarray] = None
        self.mu = self.sd = None

    def fit(self, X, y):
        self.mu, self.sd = _standardize_fit(X)
        self.X = (X - self.mu) / self.sd
        self.z = self._encode_y(y)
        return self

    def predict(self, X):
        Z = (X - self.mu) / self.sd
        d2 = ((Z[:, None, :] - self.X[None, :, :]) ** 2).sum(-1)
        k = min(self.k, len(self.X))
        nn = np.argpartition(d2, k - 1, axis=1)[:, :k]
        # inverse-distance weighting (ties at d=0 handled by epsilon)
        w = 1.0 / (np.take_along_axis(d2, nn, axis=1) + 1e-9)
        zs = self.z[nn]
        return self._decode_y((zs * w).sum(1) / w.sum(1))

    def to_state(self):
        return {"k": np.int64(self.k), "X": self.X, "z": self.z, "mu": self.mu, "sd": self.sd}

    @classmethod
    def from_state(cls, s):
        m = cls(int(s["k"]))
        m.X, m.z, m.mu, m.sd = s["X"], s["z"], s["mu"], s["sd"]
        return m


# ---------------------------------------------------------------------------
# decision tree + ensembles
# ---------------------------------------------------------------------------


@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0  # leaf prediction (log space)


class DecisionTreeRegressor(Regressor, _LogSpaceMixin):
    name = "tree5"

    def __init__(self, max_depth: int = 5, min_leaf: int = 2) -> None:
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.nodes: List[_Node] = []

    # -- fitting -----------------------------------------------------------
    def _best_split(self, X, z, sw):
        best = (None, None, np.inf)
        n, d = X.shape
        for f in range(d):
            order = np.argsort(X[:, f], kind="stable")
            xs, zs, ws = X[order, f], z[order], sw[order]
            cw = np.cumsum(ws)
            cz = np.cumsum(ws * zs)
            cz2 = np.cumsum(ws * zs * zs)
            tot_w, tot_z, tot_z2 = cw[-1], cz[-1], cz2[-1]
            for i in range(self.min_leaf - 1, n - self.min_leaf):
                if xs[i] == xs[i + 1]:
                    continue
                lw, lz, lz2 = cw[i], cz[i], cz2[i]
                rw, rz, rz2 = tot_w - lw, tot_z - lz, tot_z2 - lz2
                sse = (lz2 - lz * lz / lw) + (rz2 - rz * rz / rw)
                if sse < best[2]:
                    best = (f, (xs[i] + xs[i + 1]) / 2.0, sse)
        return best

    def _grow(self, X, z, sw, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(np.average(z, weights=sw))))
        if depth >= self.max_depth or len(X) < 2 * self.min_leaf or np.ptp(z) < 1e-12:
            return idx
        f, t, _ = self._best_split(X, z, sw)
        if f is None:
            return idx
        m = X[:, f] <= t
        node = self.nodes[idx]
        node.feature, node.thresh = f, t
        node.left = self._grow(X[m], z[m], sw[m], depth + 1)
        node.right = self._grow(X[~m], z[~m], sw[~m], depth + 1)
        return idx

    def fit(self, X, y, sample_weight: Optional[np.ndarray] = None):
        self.nodes = []
        sw = np.ones(len(X)) if sample_weight is None else sample_weight
        self._grow(np.asarray(X, float), self._encode_y(np.asarray(y, float)), sw, 0)
        return self

    def fit_log(self, X, z, sw=None):
        """Fit directly on log-space residuals (for boosting)."""
        self.nodes = []
        sw = np.ones(len(X)) if sw is None else sw
        self._grow(np.asarray(X, float), np.asarray(z, float), sw, 0)
        return self

    def _predict_log(self, X):
        out = np.empty(len(X))
        for i, x in enumerate(np.asarray(X, float)):
            n = 0
            while self.nodes[n].feature >= 0:
                n = self.nodes[n].left if x[self.nodes[n].feature] <= self.nodes[n].thresh else self.nodes[n].right
            out[i] = self.nodes[n].value
        return out

    def predict(self, X):
        return self._decode_y(self._predict_log(X))

    def to_state(self):
        arr = np.array(
            [(n.feature, n.thresh, n.left, n.right, n.value) for n in self.nodes],
            dtype=np.float64,
        )
        return {"nodes": arr, "max_depth": np.int64(self.max_depth)}

    @classmethod
    def from_state(cls, s):
        m = cls(int(s["max_depth"]))
        m.nodes = [
            _Node(int(f), float(t), int(l), int(r), float(v))
            for f, t, l, r, v in s["nodes"]
        ]
        return m


class RandomForestRegressor(Regressor, _LogSpaceMixin):
    name = "forest"

    def __init__(self, n_estimators: int = 50, max_depth: int = 6, seed: int = 0):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.seed = seed
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        z = self._encode_y(np.asarray(y, float))
        self.trees = []
        for _ in range(self.n_estimators):
            idx = rng.integers(0, len(X), len(X))
            t = DecisionTreeRegressor(self.max_depth)
            t.fit_log(X[idx], z[idx])
            self.trees.append(t)
        return self

    def predict(self, X):
        zs = np.mean([t._predict_log(X) for t in self.trees], axis=0)
        return self._decode_y(zs)

    def to_state(self):
        return {
            "n": np.int64(len(self.trees)),
            **{f"tree{i}": t.to_state()["nodes"] for i, t in enumerate(self.trees)},
        }

    @classmethod
    def from_state(cls, s):
        m = cls(int(s["n"]))
        m.trees = [
            DecisionTreeRegressor.from_state(
                {"nodes": s[f"tree{i}"], "max_depth": np.int64(0)}
            )
            for i in range(int(s["n"]))
        ]
        return m


class GradientBoostRegressor(Regressor, _LogSpaceMixin):
    name = "gboost"

    def __init__(self, n_estimators: int = 100, lr: float = 0.1, max_depth: int = 3):
        self.n_estimators = n_estimators
        self.lr = lr
        self.max_depth = max_depth
        self.base = 0.0
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, X, y):
        z = self._encode_y(np.asarray(y, float))
        self.base = float(z.mean())
        resid = z - self.base
        self.trees = []
        for _ in range(self.n_estimators):
            t = DecisionTreeRegressor(self.max_depth)
            t.fit_log(X, resid)
            resid = resid - self.lr * t._predict_log(X)
            self.trees.append(t)
        return self

    def predict(self, X):
        z = np.full(len(X), self.base)
        for t in self.trees:
            z += self.lr * t._predict_log(X)
        return self._decode_y(z)

    def to_state(self):
        return {
            "n": np.int64(len(self.trees)),
            "base": np.float64(self.base),
            "lr": np.float64(self.lr),
            **{f"tree{i}": t.to_state()["nodes"] for i, t in enumerate(self.trees)},
        }

    @classmethod
    def from_state(cls, s):
        m = cls(int(s["n"]), float(s["lr"]))
        m.base = float(s["base"])
        m.trees = [
            DecisionTreeRegressor.from_state(
                {"nodes": s[f"tree{i}"], "max_depth": np.int64(0)}
            )
            for i in range(int(s["n"]))
        ]
        return m


MODEL_ZOO = {
    m.name: m
    for m in (
        LinearRegression,
        PolynomialRegression,
        KNNRegressor,
        DecisionTreeRegressor,
        RandomForestRegressor,
        GradientBoostRegressor,
    )
}


def make(name: str) -> Regressor:
    return MODEL_ZOO[name]()
