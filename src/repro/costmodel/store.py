"""Learned dictionary cost model Δ + its on-disk store.

The paper's best method — **individual models with feature engineering** —
is the default: one regressor per (backend, op, orderedness) trained on
``[size, n, log2 size, log2 n]`` features.  The store persists both the raw
profiling table and the fitted model states to ``var/costmodel/`` so the
installation stage runs once per machine.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import AnalyticCostModel
from . import regression
from .profiler import OPS, ProfileTable, profile, profile_quick

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "var", "costmodel")

Key = Tuple[str, str, bool]  # (ds, op, ordered)


@dataclass
class LearnedCostModel:
    """Δ implementation backed by per-(ds, op, ordered) regressors."""

    models: Dict[Key, regression.Regressor]
    model_name: str = "knn4"
    log_features: bool = True  # featurization used at fit time

    def op_cost(self, ds: str, op: str, n: float, size: float, ordered: bool) -> float:
        if n <= 0:
            return 0.0
        key = (ds, op, bool(ordered))
        if key not in self.models:
            # backend profiled only without ordering distinction, or unseen:
            key = (ds, op, False)
        if key not in self.models:
            return AnalyticCostModel().op_cost(ds, op, n, size, ordered)
        X = np.array([[max(size, 1.0), max(n, 1.0)]], float)
        if self.log_features:
            X = regression.with_log_features(X)
        sec = float(self.models[key].predict(X)[0])
        # profiling covers n in [size/4, 4·size]; extrapolate linearly in n
        # beyond the profiled ratio range (costs are per-batch)
        return max(sec, 0.0)


def train(
    table: ProfileTable, model_name: str = "knn4", log_features: bool = True
) -> LearnedCostModel:
    models: Dict[Key, regression.Regressor] = {}
    combos = {(r.ds, r.op, r.ordered) for r in table.rows}
    for ds, op, ordered in sorted(combos):
        sub = table.filter(ds=ds, op=op, ordered=ordered)
        X, y = sub.features_labels()
        if log_features:
            X = regression.with_log_features(X)
        m = regression.make(model_name)
        m.fit(X, y)
        models[(ds, op, ordered)] = m
    return LearnedCostModel(models, model_name, log_features)


def train_all_in_one(
    table: ProfileTable, model_name: str = "knn4"
) -> "AllInOneCostModel":
    X, y = table.onehot_features_labels()
    Xl = np.concatenate([X[:, :2], np.log2(np.maximum(X[:, :2], 1.0)), X[:, 2:]], axis=1)
    m = regression.make(model_name)
    m.fit(Xl, y)
    ds_names = sorted({r.ds for r in table.rows})
    return AllInOneCostModel(m, ds_names)


@dataclass
class AllInOneCostModel:
    """The paper's §6.2.1 'All in One Model' baseline featurization."""

    model: regression.Regressor
    ds_names: Sequence[str]

    def op_cost(self, ds: str, op: str, n: float, size: float, ordered: bool) -> float:
        if n <= 0:
            return 0.0
        row = [max(size, 1.0), max(n, 1.0)]
        row += [np.log2(row[0]), np.log2(row[1]), float(ordered)]
        row += [1.0 if ds == d else 0.0 for d in self.ds_names]
        row += [1.0 if op == o else 0.0 for o in OPS]
        return max(float(self.model.predict(np.array([row]))[0]), 0.0)


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def _key_str(key: Key) -> str:
    return f"{key[0]}|{key[1]}|{int(key[2])}"


def save_model(model: LearnedCostModel, directory: str = DEFAULT_DIR) -> None:
    os.makedirs(directory, exist_ok=True)
    blob: Dict[str, np.ndarray] = {"__model_name__": np.array(model.model_name)}
    for key, reg in model.models.items():
        for sname, arr in reg.to_state().items():
            blob[f"{_key_str(key)}::{sname}"] = np.asarray(arr)
    np.savez(os.path.join(directory, "delta.npz"), **blob)


def load_model(directory: str = DEFAULT_DIR) -> Optional[LearnedCostModel]:
    path = os.path.join(directory, "delta.npz")
    if not os.path.exists(path):
        return None
    blob = np.load(path, allow_pickle=False)
    model_name = str(blob["__model_name__"])
    states: Dict[Key, Dict[str, np.ndarray]] = {}
    for full in blob.files:
        if full == "__model_name__":
            continue
        keypart, sname = full.split("::")
        ds, op, o = keypart.split("|")
        key = (ds, op, bool(int(o)))
        states.setdefault(key, {})[sname] = blob[full]
    cls = regression.MODEL_ZOO[model_name]
    models = {k: cls.from_state(s) for k, s in states.items()}
    return LearnedCostModel(models, model_name)


def install(
    directory: str = DEFAULT_DIR,
    quick: bool = False,
    model_name: str = "knn4",
    verbose: bool = False,
) -> LearnedCostModel:
    """The full installation stage: profile + train + persist.  Reuses an
    existing installation unless absent."""
    existing = load_model(directory)
    if existing is not None:
        return existing
    table = profile_quick(verbose=verbose) if quick else profile(verbose=verbose)
    os.makedirs(directory, exist_ok=True)
    table.save(os.path.join(directory, "profile.npy"))
    model = train(table, model_name=model_name)
    save_model(model, directory)
    return model


def load_profile(directory: str = DEFAULT_DIR) -> Optional[ProfileTable]:
    path = os.path.join(directory, "profile.npy")
    if not os.path.exists(path):
        return None
    return ProfileTable.load(path)
