"""Learned MoE-dispatch cost model — the paper's installation stage applied
to the LM-side dictionary choice (DESIGN.md §5).

Profiles ``positions_sort`` vs ``positions_scatter`` over (n_tokens,
n_experts) on the current machine, fits one regressor per strategy, and
persists them.  ``auto_dispatch`` then consults :func:`load_dispatch_model`
— the dispatch decision is *learned per machine*, exactly like the paper's
dictionary choice, instead of the analytic crossover fallback.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import regression
from .store import DEFAULT_DIR

_PATH = "moe_dispatch.npz"


@dataclass
class DispatchModel:
    models: Dict[str, regression.Regressor]

    def choose(self, n_tokens: int, n_experts: int) -> str:
        X = regression.with_log_features(
            np.array([[float(n_tokens), float(n_experts)]])
        )
        t_sort = float(self.models["sort"].predict(X)[0])
        t_scatter = float(self.models["scatter"].predict(X)[0])
        return "sort" if t_sort <= t_scatter else "scatter"


def profile_dispatch(
    token_counts=(1024, 8192, 65536),
    expert_counts=(8, 32, 128),
    repeats: int = 3,
    seed: int = 0,
):
    from repro.models import moe as M

    rng = np.random.default_rng(seed)
    rows = []  # (strategy, n_tokens, n_experts, seconds)
    for n in token_counts:
        for e in expert_counts:
            eid = jnp.asarray(rng.integers(0, e, n).astype(np.int32))
            for name, fn in (
                ("sort", jax.jit(lambda x, _e=e: M.positions_sort(x, _e))),
                ("scatter", jax.jit(lambda x, _e=e: M.positions_scatter(x, _e))),
            ):
                out = fn(eid)
                jax.block_until_ready(out)
                ts = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(eid))
                    ts.append(time.perf_counter() - t0)
                rows.append((name, n, e, float(np.median(ts))))
    return rows


def install_dispatch(directory: str = DEFAULT_DIR, **kw) -> DispatchModel:
    rows = profile_dispatch(**kw)
    models = {}
    blob = {}
    for strat in ("sort", "scatter"):
        sub = [(n, e, s) for name, n, e, s in rows if name == strat]
        X = regression.with_log_features(np.array([[n, e] for n, e, _ in sub], float))
        y = np.array([s for _, _, s in sub])
        m = regression.make("knn4").fit(X, y)
        models[strat] = m
        for k, v in m.to_state().items():
            blob[f"{strat}::{k}"] = np.asarray(v)
    os.makedirs(directory, exist_ok=True)
    np.savez(os.path.join(directory, _PATH), **blob)
    return DispatchModel(models)


def load_dispatch_model(directory: str = DEFAULT_DIR) -> Optional[DispatchModel]:
    path = os.path.join(directory, _PATH)
    if not os.path.exists(path):
        return None
    blob = np.load(path)
    states: Dict[str, Dict[str, np.ndarray]] = {}
    for full in blob.files:
        strat, k = full.split("::")
        states.setdefault(strat, {})[k] = blob[full]
    return DispatchModel(
        {s: regression.KNNRegressor.from_state(st) for s, st in states.items()}
    )
